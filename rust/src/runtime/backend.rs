//! The [`Backend`] abstraction: everything the cascade, server and
//! experiment layers need from an inference substrate.
//!
//! The ARI decision policy (margin thresholding, escalation, energy
//! accounting) is independent of *how* a resolution variant is executed.
//! This trait captures the execution contract — compile-by-variant,
//! execute a fixed-size batch into [`BatchOutputs`], weight/dataset
//! lifecycle — so the same coordinator serves:
//!
//! * [`crate::runtime::NativeBackend`] — pure rust, self-contained,
//!   builds and tests offline with zero native dependencies; and
//! * `pjrt::Engine` (behind the `pjrt` cargo feature) — the PJRT client
//!   executing the AOT-lowered JAX/Pallas HLO artifacts.
//!
//! The trait is object-safe: runtime backend selection goes through
//! `Box<dyn Backend>` (see [`open_backend`]).

use std::path::Path;

use crate::data::{EvalData, Manifest, VariantKind, VariantRef, Weights};

/// Outputs of one executed batch.
#[derive(Clone, Debug)]
pub struct BatchOutputs {
    /// Row-major `(batch, n_classes)` scores (L2-normalised logits).
    pub scores: Vec<f32>,
    /// Predicted class per row.
    pub pred: Vec<i32>,
    /// Top-1 minus top-2 score gap per row — the ARI decision signal.
    pub margin: Vec<f32>,
    /// Number of rows.
    pub batch: usize,
    /// Number of classes per row.
    pub n_classes: usize,
}

impl BatchOutputs {
    /// Accuracy against labels.
    pub fn accuracy(&self, labels: &[i32]) -> f64 {
        assert_eq!(labels.len(), self.pred.len());
        if labels.is_empty() {
            return 0.0;
        }
        let ok = self.pred.iter().zip(labels).filter(|(a, b)| a == b).count();
        ok as f64 / labels.len() as f64
    }

    /// One row of scores.
    pub fn score_row(&self, i: usize) -> &[f32] {
        &self.scores[i * self.n_classes..(i + 1) * self.n_classes]
    }
}

/// Per-variant compile/execute accounting — the machine-readable perf
/// record behind `BENCH_native.json` (see `util::benchkit`).
#[derive(Clone, Debug, Default)]
pub struct VariantStats {
    /// Stable variant key (`dataset/Kind<level>`).
    pub key: String,
    /// Wall time spent preparing/compiling this variant (ns).
    pub prepare_ns: u128,
    /// Batches executed on this variant.
    pub executes: u64,
    /// Total execute wall time (ns).
    pub execute_ns: u128,
    /// Samples (rows) pushed through this variant.
    pub samples: u64,
}

impl VariantStats {
    /// Mean execute wall time per sample (ns); 0 before any execute.
    pub fn ns_per_sample(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.execute_ns as f64 / self.samples as f64
        }
    }

    /// Throughput in samples per second of execute wall time.
    pub fn samples_per_sec(&self) -> f64 {
        if self.execute_ns == 0 {
            0.0
        } else {
            self.samples as f64 / (self.execute_ns as f64 / 1e9)
        }
    }
}

/// Compile/execute statistics (perf accounting), shared by all backends.
///
/// This is the *report* shape: backends accumulate wall time in integer
/// nanoseconds ([`EngineStatsAccum`]) and derive these µs/ms fields at
/// read time, rounded to nearest — truncating per call (the old
/// `execute_us += elapsed.as_micros()`) lost up to 1 µs *per execute*,
/// systematically down, the same bias-down class as the metrics energy
/// counter fixed in PR 3.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// Variants compiled (or prepared) so far.
    pub compiles: u64,
    /// Total wall time spent compiling, in milliseconds (derived from
    /// the nanosecond accumulator, rounded to nearest).
    pub compile_ms: u128,
    /// Batches executed.
    pub executes: u64,
    /// Total wall time spent executing, in microseconds (derived from
    /// the nanosecond accumulator, rounded to nearest).
    pub execute_us: u128,
    /// Host-to-device bytes uploaded (0 for host-resident backends).
    pub h2d_bytes: u64,
}

/// The internal accumulator behind [`EngineStats`]: integer nanoseconds,
/// summed exactly; [`EngineStatsAccum::report`] derives the public µs/ms
/// fields once, at read time.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStatsAccum {
    /// Variants compiled (or prepared) so far.
    pub compiles: u64,
    /// Total compile wall time, exact nanoseconds.
    pub compile_ns: u128,
    /// Batches executed.
    pub executes: u64,
    /// Total execute wall time, exact nanoseconds.
    pub execute_ns: u128,
    /// Host-to-device bytes uploaded.
    pub h2d_bytes: u64,
}

impl EngineStatsAccum {
    /// Derive the public report: µs/ms rounded to nearest (never the
    /// truncate-per-call bias the accumulator exists to avoid).
    pub fn report(&self) -> EngineStats {
        EngineStats {
            compiles: self.compiles,
            compile_ms: (self.compile_ns + 500_000) / 1_000_000,
            executes: self.executes,
            execute_us: (self.execute_ns + 500) / 1_000,
            h2d_bytes: self.h2d_bytes,
        }
    }
}

/// An inference substrate the ARI coordinator can serve from.
///
/// Implementations provide dataset/weight lifecycle, per-variant
/// compilation and fixed-size batch execution; the padding/chunking
/// conveniences ([`Backend::run_padded`], [`Backend::run_dataset`]) are
/// provided methods shared by every backend.
///
/// ```
/// use ari::data::VariantKind;
/// use ari::runtime::{Backend, NativeBackend};
///
/// let mut backend = NativeBackend::synthetic();
/// let ds = backend.manifest().datasets[0].name.clone();
/// let v = backend.manifest().variant(&ds, VariantKind::Fp, 16, 32).unwrap().clone();
/// let eval = backend.eval_data(&ds).unwrap();
/// let (out, waste) = backend.run_padded(&v, eval.rows(0, 4), 4, None).unwrap();
/// assert_eq!(out.pred.len(), 4);
/// assert_eq!(waste, 28); // 4 rows padded into the compiled batch of 32
/// ```
pub trait Backend {
    /// Short human-readable backend name (`"native"`, `"pjrt"`).
    fn name(&self) -> &'static str;

    /// The variant/dataset manifest this backend serves.
    fn manifest(&self) -> &Manifest;

    /// Ensure a dataset's weights (and eval data, where applicable) are
    /// loaded and ready for execution.  Idempotent.
    fn load_dataset(&mut self, name: &str) -> crate::Result<()>;

    /// Loaded weights of a dataset (for cross-check engines and the
    /// energy model's topology scaling).  The dataset must have been
    /// loaded via [`Backend::load_dataset`] first.
    fn weights(&self, name: &str) -> crate::Result<&Weights>;

    /// The eval split of a dataset.
    fn eval_data(&self, name: &str) -> crate::Result<EvalData>;

    /// Compile (or fetch from cache) a variant's executable.  Idempotent.
    fn ensure_compiled(&mut self, v: &VariantRef) -> crate::Result<()>;

    /// Execute one batch on a variant.  `x` must be exactly
    /// `v.batch * input_dim` long (use [`Backend::run_padded`] for
    /// partial batches).  `sc_key` is required for SC variants (the same
    /// key always reproduces the same stochastic stream) and ignored for
    /// FP variants.
    fn execute(&mut self, v: &VariantRef, x: &[f32], sc_key: Option<[u32; 2]>) -> crate::Result<BatchOutputs>;

    /// Hand a consumed [`BatchOutputs`] back to the backend so its
    /// buffers can be reused by a later [`Backend::execute`].  Purely an
    /// optimisation hook for the serving hot path (zero steady-state
    /// allocation): the default implementation just drops the outputs,
    /// and callers are free to never call it.
    fn recycle_outputs(&mut self, _out: BatchOutputs) {}

    /// Compile/execute statistics accumulated so far.
    fn stats(&self) -> EngineStats;

    /// Per-variant timing breakdown, sorted by key.  Backends that do
    /// not track per-variant timings return an empty vec.
    fn variant_stats(&self) -> Vec<VariantStats> {
        Vec::new()
    }

    /// Execute `n <= v.batch` rows by zero-padding to the compiled batch
    /// size; outputs are truncated back to `n`.  Returns the padding
    /// waste (unused slots) for the metrics.
    fn run_padded(
        &mut self,
        v: &VariantRef,
        x: &[f32],
        n: usize,
        sc_key: Option<[u32; 2]>,
    ) -> crate::Result<(BatchOutputs, usize)> {
        let input_dim = self.manifest().dataset(&v.dataset)?.input_dim;
        anyhow::ensure!(n > 0 && n <= v.batch, "n={n} out of range for batch {}", v.batch);
        anyhow::ensure!(x.len() == n * input_dim, "input length mismatch");
        let waste = v.batch - n;
        let out = if waste == 0 {
            self.execute(v, x, sc_key)?
        } else {
            let mut padded = vec![0.0f32; v.batch * input_dim];
            padded[..x.len()].copy_from_slice(x);
            let mut o = self.execute(v, &padded, sc_key)?;
            o.scores.truncate(n * o.n_classes);
            o.pred.truncate(n);
            o.margin.truncate(n);
            o.batch = n;
            o
        };
        Ok((out, waste))
    }

    /// Run a whole dataset through a variant (chunked by the variant's
    /// batch size, last chunk padded).  For SC variants each chunk gets
    /// key `[seed, chunk_index]` — deterministic and chunk-decorrelated.
    fn run_dataset(&mut self, v: &VariantRef, data: &EvalData, seed: u32) -> crate::Result<BatchOutputs> {
        let mut scores = Vec::with_capacity(data.n * 10);
        let mut pred = Vec::with_capacity(data.n);
        let mut margin = Vec::with_capacity(data.n);
        let mut n_classes = 0;
        let mut chunk = 0u32;
        let mut lo = 0usize;
        while lo < data.n {
            let hi = (lo + v.batch).min(data.n);
            let key = match v.kind {
                VariantKind::Sc => Some([seed, chunk]),
                VariantKind::Fp => None,
            };
            let (out, _) = self.run_padded(v, data.rows(lo, hi), hi - lo, key)?;
            n_classes = out.n_classes;
            scores.extend_from_slice(&out.scores);
            pred.extend_from_slice(&out.pred);
            margin.extend_from_slice(&out.margin);
            lo = hi;
            chunk += 1;
        }
        Ok(BatchOutputs { scores, pred, margin, batch: data.n, n_classes })
    }

    /// Mean execute time per batch (µs).
    fn mean_execute_us(&self) -> f64 {
        let stats = self.stats();
        if stats.executes == 0 {
            0.0
        } else {
            stats.execute_us as f64 / stats.executes as f64
        }
    }
}

/// Which backend [`open_backend`] should construct.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// PJRT when compiled in and artifacts exist, else native.
    Auto,
    /// The pure-rust [`crate::runtime::NativeBackend`].
    Native,
    /// The PJRT engine (requires the `pjrt` cargo feature).
    Pjrt,
}

impl BackendKind {
    /// Parse `auto | native | pjrt`.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "auto" => Ok(BackendKind::Auto),
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => anyhow::bail!("unknown backend {other:?} (auto|native|pjrt)"),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendKind::Auto => write!(f, "auto"),
            BackendKind::Native => write!(f, "native"),
            BackendKind::Pjrt => write!(f, "pjrt"),
        }
    }
}

/// Construct a backend.
///
/// * [`BackendKind::Native`] — artifacts directory if it has a manifest,
///   otherwise the deterministic synthetic fixture suite (fully offline).
/// * [`BackendKind::Pjrt`] — the PJRT engine over `artifacts` (errors
///   unless built with `--features pjrt`).
/// * [`BackendKind::Auto`] — PJRT when compiled in *and* artifacts
///   exist; else native.
pub fn open_backend(artifacts: &Path, kind: BackendKind) -> crate::Result<Box<dyn Backend>> {
    let have_artifacts = artifacts.join("manifest.txt").exists();
    #[cfg(feature = "pjrt")]
    {
        if kind == BackendKind::Pjrt {
            return Ok(Box::new(crate::runtime::pjrt::Engine::new(artifacts)?));
        }
        if kind == BackendKind::Auto && have_artifacts {
            // Auto means "PJRT when available": a failed client
            // construction (e.g. the compile-only xla stub is linked, or
            // libxla_extension is missing) falls back to native rather
            // than failing the whole run.
            match crate::runtime::pjrt::Engine::new(artifacts) {
                Ok(engine) => return Ok(Box::new(engine)),
                Err(e) => eprintln!("[ari] PJRT unavailable ({e}); falling back to the native backend"),
            }
        }
    }
    #[cfg(not(feature = "pjrt"))]
    if kind == BackendKind::Pjrt {
        anyhow::bail!("this binary was built without the `pjrt` feature; rebuild with --features pjrt");
    }
    // Native path (explicit, or the auto fallback).
    if have_artifacts {
        Ok(Box::new(crate::runtime::NativeBackend::from_artifacts(artifacts)?))
    } else {
        Ok(Box::new(crate::runtime::NativeBackend::synthetic()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_outputs_accuracy() {
        let o = BatchOutputs { scores: vec![0.0; 6], pred: vec![1, 2, 3], margin: vec![0.1; 3], batch: 3, n_classes: 2 };
        assert!((o.accuracy(&[1, 2, 0]) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn score_row_indexing() {
        let o = BatchOutputs {
            scores: vec![0.1, 0.9, 0.8, 0.2],
            pred: vec![1, 0],
            margin: vec![0.8, 0.6],
            batch: 2,
            n_classes: 2,
        };
        assert_eq!(o.score_row(1), &[0.8, 0.2]);
    }

    #[test]
    fn stats_accum_sums_ns_and_rounds_at_read_time() {
        // 1000 × 900 ns of execute: per-call truncation to µs would
        // report 0; the ns accumulator reports 900 µs.  Same for 1500 ×
        // 700 µs of compile time vs per-call ms truncation.
        let mut acc = EngineStatsAccum::default();
        for _ in 0..1000 {
            acc.executes += 1;
            acc.execute_ns += 900;
        }
        for _ in 0..1500 {
            acc.compiles += 1;
            acc.compile_ns += 700_000;
        }
        acc.h2d_bytes = 42;
        let report = acc.report();
        assert_eq!(report.execute_us, 900);
        assert_eq!(report.compile_ms, 1050);
        assert_eq!(report.executes, 1000);
        assert_eq!(report.compiles, 1500);
        assert_eq!(report.h2d_bytes, 42);
        // Rounds to nearest, not down.
        let half = EngineStatsAccum { execute_ns: 1_500, compile_ns: 1_500_000, ..Default::default() };
        assert_eq!(half.report().execute_us, 2);
        assert_eq!(half.report().compile_ms, 2);
    }

    #[test]
    fn variant_stats_rates() {
        let mut s = VariantStats { key: "d/Fp16".into(), ..Default::default() };
        assert_eq!(s.ns_per_sample(), 0.0);
        assert_eq!(s.samples_per_sec(), 0.0);
        s.executes = 2;
        s.samples = 64;
        s.execute_ns = 64_000;
        assert!((s.ns_per_sample() - 1000.0).abs() < 1e-9);
        assert!((s.samples_per_sec() - 1e6).abs() < 1e-3);
    }

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("auto").unwrap(), BackendKind::Auto);
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("xla").is_err());
        assert_eq!(BackendKind::Native.to_string(), "native");
    }

    #[test]
    fn open_backend_native_falls_back_to_synthetic() {
        let b = open_backend(Path::new("/nonexistent-artifacts"), BackendKind::Native).unwrap();
        assert_eq!(b.name(), "native");
        assert!(!b.manifest().datasets.is_empty());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn open_backend_pjrt_errors_without_feature() {
        let err = open_backend(Path::new("/nonexistent-artifacts"), BackendKind::Pjrt).unwrap_err();
        assert!(err.to_string().contains("pjrt"));
    }
}
