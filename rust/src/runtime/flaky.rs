//! A deterministic fault-wrapping backend for tests and benches.
//!
//! [`FlakyBackend`] delegates every [`Backend`] method to an inner
//! backend and fails (or panics) on *exact* `execute` call indices.
//! Where the probabilistic registry in [`crate::util::fault`] models a
//! noisy environment, this wrapper answers a different question the
//! model suites need: *what happens when call #k of a schedule fails?*
//! — every schedule of the deterministic harness then sees the same
//! fault at the same dispatch, so the exactly-one-completion invariant
//! can be checked per failure position rather than on average.

use crate::data::{EvalData, Manifest, VariantRef, Weights};
use crate::runtime::{Backend, BatchOutputs, EngineStats, VariantStats};

/// Wraps a [`Backend`], failing chosen `execute` calls deterministically.
///
/// Call indices are 0-based and count every `execute` arriving at this
/// wrapper (including those issued through the provided `run_padded` /
/// `run_dataset` helpers, which funnel into `execute`).
pub struct FlakyBackend<B: Backend> {
    inner: B,
    /// 0-based `execute` call indices that return a typed error.
    fail_on: Vec<u64>,
    /// 0-based `execute` call indices that panic.
    panic_on: Vec<u64>,
    calls: u64,
}

impl<B: Backend> FlakyBackend<B> {
    /// Wrap `inner` with no faults scheduled.
    pub fn new(inner: B) -> Self {
        Self { inner, fail_on: Vec::new(), panic_on: Vec::new(), calls: 0 }
    }

    /// Schedule a typed `Err` on the given 0-based `execute` call index.
    pub fn fail_on_call(mut self, idx: u64) -> Self {
        self.fail_on.push(idx);
        self
    }

    /// Schedule a panic on the given 0-based `execute` call index.
    pub fn panic_on_call(mut self, idx: u64) -> Self {
        self.panic_on.push(idx);
        self
    }

    /// `execute` calls seen so far (failed, panicked and successful).
    pub fn calls(&self) -> u64 {
        self.calls
    }

    /// The wrapped backend.
    pub fn inner_mut(&mut self) -> &mut B {
        &mut self.inner
    }
}

impl<B: Backend> Backend for FlakyBackend<B> {
    fn name(&self) -> &'static str {
        "flaky"
    }

    fn manifest(&self) -> &Manifest {
        self.inner.manifest()
    }

    fn load_dataset(&mut self, name: &str) -> crate::Result<()> {
        self.inner.load_dataset(name)
    }

    fn weights(&self, name: &str) -> crate::Result<&Weights> {
        self.inner.weights(name)
    }

    fn eval_data(&self, name: &str) -> crate::Result<EvalData> {
        self.inner.eval_data(name)
    }

    fn ensure_compiled(&mut self, v: &VariantRef) -> crate::Result<()> {
        self.inner.ensure_compiled(v)
    }

    fn execute(&mut self, v: &VariantRef, x: &[f32], sc_key: Option<[u32; 2]>) -> crate::Result<BatchOutputs> {
        let idx = self.calls;
        self.calls += 1;
        if self.panic_on.contains(&idx) {
            panic!("flaky backend: scheduled panic on execute call {idx}");
        }
        if self.fail_on.contains(&idx) {
            anyhow::bail!("flaky backend: scheduled failure on execute call {idx}");
        }
        self.inner.execute(v, x, sc_key)
    }

    fn recycle_outputs(&mut self, out: BatchOutputs) {
        self.inner.recycle_outputs(out)
    }

    fn stats(&self) -> EngineStats {
        self.inner.stats()
    }

    fn variant_stats(&self) -> Vec<VariantStats> {
        self.inner.variant_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::VariantKind;
    use crate::runtime::fixture::FixtureSpec;
    use crate::runtime::NativeBackend;

    #[test]
    fn fails_exactly_the_scheduled_calls() {
        let native = NativeBackend::from_fixtures(&[FixtureSpec::small("d", "D", 16, 11)]);
        let mut b = FlakyBackend::new(native).fail_on_call(1);
        let v = b.manifest().variant("d", VariantKind::Fp, 16, 32).unwrap().clone();
        let eval = b.eval_data("d").unwrap();
        assert!(b.execute(&v, eval.rows(0, 32), None).is_ok(), "call 0 clean");
        let err = b.execute(&v, eval.rows(0, 32), None).unwrap_err().to_string();
        assert!(err.contains("call 1"), "{err}");
        assert!(b.execute(&v, eval.rows(0, 32), None).is_ok(), "call 2 clean again");
        assert_eq!(b.calls(), 3);
    }

    #[test]
    fn panics_on_schedule_and_counts_the_call() {
        let native = NativeBackend::from_fixtures(&[FixtureSpec::small("d", "D", 16, 11)]);
        let mut b = FlakyBackend::new(native).panic_on_call(0);
        let v = b.manifest().variant("d", VariantKind::Fp, 16, 32).unwrap().clone();
        let eval = b.eval_data("d").unwrap();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = b.execute(&v, eval.rows(0, 32), None);
        }));
        assert!(caught.is_err());
        assert!(b.execute(&v, eval.rows(0, 32), None).is_ok(), "wrapper survives its own panic");
    }
}
