//! PJRT runtime: load AOT-lowered HLO text, compile once, execute from
//! the serving hot path.
//!
//! Wraps the `xla` crate (PJRT C API, CPU client).  Weights are uploaded
//! to device buffers **once per dataset** at startup; each inference call
//! only uploads the activation batch (and, for SC variants, the 8-byte
//! threefry key).  Executables are compiled lazily and cached by variant
//! key.
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so an [`Engine`] must stay on
//! the thread that created it — the server keeps all PJRT work on the
//! coordinator thread and feeds it through channels (see
//! [`crate::server`]).

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use crate::data::{EvalData, Manifest, VariantKind, VariantRef, Weights};

/// Outputs of one executed batch.
#[derive(Clone, Debug)]
pub struct BatchOutputs {
    /// Row-major (batch, n_classes) softmax scores.
    pub scores: Vec<f32>,
    pub pred: Vec<i32>,
    pub margin: Vec<f32>,
    pub batch: usize,
    pub n_classes: usize,
}

struct DatasetState {
    weights: Weights,
    /// Device-resident raw (f32) weight buffers, exporter order — used by
    /// SC variants (which never quantise weights).
    bufs: Vec<xla::PjRtBuffer>,
    /// Per-FP-level pre-quantised weight buffers.  The L1 kernel contract
    /// is that FP weights arrive already quantised (quantisation is
    /// idempotent and batch-independent, so it is hoisted off the
    /// per-call hot path — §Perf in EXPERIMENTS.md).
    fp_bufs: HashMap<u32, Vec<xla::PjRtBuffer>>,
    input_dim: usize,
}

/// Compile/execute statistics (perf accounting).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub compiles: u64,
    pub compile_ms: u128,
    pub executes: u64,
    pub execute_us: u128,
    pub h2d_bytes: u64,
}

/// The PJRT engine: one per process/thread.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    datasets: HashMap<String, DatasetState>,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    pub stats: EngineStats,
}

impl Engine {
    /// Create a CPU PJRT client and parse the artifact manifest.
    /// Weights/eval data load lazily per dataset.
    pub fn new(artifacts: &Path) -> crate::Result<Self> {
        let manifest = Manifest::load(artifacts)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?;
        Ok(Self { client, manifest, datasets: HashMap::new(), executables: HashMap::new(), stats: EngineStats::default() })
    }

    /// Ensure a dataset's weights are loaded and device-resident.
    pub fn load_dataset(&mut self, name: &str) -> crate::Result<()> {
        if self.datasets.contains_key(name) {
            return Ok(());
        }
        let entry = self.manifest.dataset(name)?.clone();
        let dir = self.manifest.dataset_dir(name);
        let weights = Weights::load(&dir)?;
        anyhow::ensure!(
            weights.layers[0].in_dim == entry.input_dim,
            "weights/manifest input_dim mismatch for {name}"
        );
        let mut bufs = Vec::new();
        for (_, dims, data) in weights.flat() {
            let buf = self
                .client
                .buffer_from_host_buffer::<f32>(data, &dims, None)
                .map_err(|e| anyhow::anyhow!("uploading weights for {name}: {e}"))?;
            self.stats.h2d_bytes += (data.len() * 4) as u64;
            bufs.push(buf);
        }
        self.datasets.insert(
            name.to_string(),
            DatasetState { weights, bufs, fp_bufs: HashMap::new(), input_dim: entry.input_dim },
        );
        Ok(())
    }

    /// Ensure pre-quantised weight buffers exist for an FP level.
    /// Quantises w tensors host-side (bit-identical to the L1 kernel's
    /// `quantize_fp`); b/alpha stay raw (the kernel quantises the bias in
    /// its epilogue).
    fn ensure_fp_weights(&mut self, name: &str, level: u32) -> crate::Result<()> {
        let ds = self.datasets.get(name).ok_or_else(|| anyhow::anyhow!("dataset {name} not loaded"))?;
        if ds.fp_bufs.contains_key(&level) {
            return Ok(());
        }
        let fmt = crate::quant::FpFormat::fp(level);
        let mut bufs = Vec::new();
        let mut h2d = 0u64;
        for (i, (_, dims, data)) in ds.weights.flat().into_iter().enumerate() {
            // flat() order is (w, b, alpha) per layer: quantise only w.
            let owned: Vec<f32> = if i % 3 == 0 {
                data.iter().map(|&v| fmt.quantize(v)).collect()
            } else {
                data.to_vec()
            };
            let buf = self
                .client
                .buffer_from_host_buffer::<f32>(&owned, &dims, None)
                .map_err(|e| anyhow::anyhow!("uploading FP{level} weights for {name}: {e}"))?;
            h2d += (owned.len() * 4) as u64;
            bufs.push(buf);
        }
        self.stats.h2d_bytes += h2d;
        self.datasets.get_mut(name).unwrap().fp_bufs.insert(level, bufs);
        Ok(())
    }

    /// Loaded weights of a dataset (for the pure-rust cross-check engines).
    pub fn weights(&self, name: &str) -> crate::Result<&Weights> {
        Ok(&self.datasets.get(name).ok_or_else(|| anyhow::anyhow!("dataset {name} not loaded"))?.weights)
    }

    /// Load the eval split of a dataset.
    pub fn eval_data(&self, name: &str) -> crate::Result<EvalData> {
        EvalData::load(&self.manifest.dataset_dir(name))
    }

    /// Compile (or fetch from cache) a variant's executable.
    pub fn ensure_compiled(&mut self, v: &VariantRef) -> crate::Result<()> {
        let key = v.key();
        if self.executables.contains_key(&key) {
            return Ok(());
        }
        let path = self.manifest.hlo_path(v);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow::anyhow!("compiling {key}: {e}"))?;
        self.stats.compiles += 1;
        self.stats.compile_ms += t0.elapsed().as_millis();
        self.executables.insert(key, exe);
        Ok(())
    }

    /// Execute one batch on a variant.  `x` must be exactly
    /// `v.batch * input_dim` long (use [`Engine::run_padded`] for partial
    /// batches).  `sc_key` is required for SC variants.
    pub fn execute(&mut self, v: &VariantRef, x: &[f32], sc_key: Option<[u32; 2]>) -> crate::Result<BatchOutputs> {
        self.ensure_compiled(v)?;
        self.load_dataset(&v.dataset)?;
        if v.kind == VariantKind::Fp {
            self.ensure_fp_weights(&v.dataset, v.level as u32)?;
        }
        let ds = &self.datasets[&v.dataset];
        anyhow::ensure!(
            x.len() == v.batch * ds.input_dim,
            "input length {} != batch {} * input_dim {}",
            x.len(),
            v.batch,
            ds.input_dim
        );
        let t0 = Instant::now();
        let xbuf = self
            .client
            .buffer_from_host_buffer::<f32>(x, &[v.batch, ds.input_dim], None)
            .map_err(|e| anyhow::anyhow!("uploading batch: {e}"))?;
        self.stats.h2d_bytes += (x.len() * 4) as u64;
        let kbuf = match (v.kind, sc_key) {
            (VariantKind::Sc, Some(k)) => Some(
                self.client
                    .buffer_from_host_buffer::<u32>(&k, &[2], None)
                    .map_err(|e| anyhow::anyhow!("uploading key: {e}"))?,
            ),
            (VariantKind::Sc, None) => anyhow::bail!("SC variant requires a key"),
            (VariantKind::Fp, _) => None,
        };
        let wbufs: &Vec<xla::PjRtBuffer> = match v.kind {
            VariantKind::Fp => &ds.fp_bufs[&(v.level as u32)],
            VariantKind::Sc => &ds.bufs,
        };
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(2 + wbufs.len());
        inputs.push(&xbuf);
        if let Some(ref k) = kbuf {
            inputs.push(k);
        }
        inputs.extend(wbufs.iter());
        let exe = &self.executables[&v.key()];
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&inputs)
            .map_err(|e| anyhow::anyhow!("executing {}: {e}", v.key()))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download: {e}"))?;
        self.stats.executes += 1;
        self.stats.execute_us += t0.elapsed().as_micros();
        let parts = result.to_tuple().map_err(|e| anyhow::anyhow!("untuple: {e}"))?;
        anyhow::ensure!(parts.len() == 3, "expected 3 outputs, got {}", parts.len());
        let scores = parts[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("scores: {e}"))?;
        let pred = parts[1].to_vec::<i32>().map_err(|e| anyhow::anyhow!("pred: {e}"))?;
        let margin = parts[2].to_vec::<f32>().map_err(|e| anyhow::anyhow!("margin: {e}"))?;
        let n_classes = scores.len() / v.batch;
        Ok(BatchOutputs { scores, pred, margin, batch: v.batch, n_classes })
    }

    /// Execute `n <= v.batch` rows by zero-padding to the compiled batch
    /// size; outputs are truncated back to `n`.  Returns the padding
    /// waste for the metrics.
    pub fn run_padded(
        &mut self,
        v: &VariantRef,
        x: &[f32],
        n: usize,
        sc_key: Option<[u32; 2]>,
    ) -> crate::Result<(BatchOutputs, usize)> {
        self.load_dataset(&v.dataset)?;
        let input_dim = self.datasets[&v.dataset].input_dim;
        anyhow::ensure!(n > 0 && n <= v.batch, "n={n} out of range for batch {}", v.batch);
        anyhow::ensure!(x.len() == n * input_dim, "input length mismatch");
        let waste = v.batch - n;
        let out = if waste == 0 {
            self.execute(v, x, sc_key)?
        } else {
            let mut padded = vec![0.0f32; v.batch * input_dim];
            padded[..x.len()].copy_from_slice(x);
            let mut o = self.execute(v, &padded, sc_key)?;
            o.scores.truncate(n * o.n_classes);
            o.pred.truncate(n);
            o.margin.truncate(n);
            o.batch = n;
            o
        };
        Ok((out, waste))
    }

    /// Run a whole dataset through a variant (chunked by the variant's
    /// batch size, last chunk padded).  For SC variants each chunk gets
    /// key `[seed, chunk_index]` — deterministic and chunk-decorrelated.
    pub fn run_dataset(&mut self, v: &VariantRef, data: &EvalData, seed: u32) -> crate::Result<BatchOutputs> {
        let mut scores = Vec::with_capacity(data.n * 10);
        let mut pred = Vec::with_capacity(data.n);
        let mut margin = Vec::with_capacity(data.n);
        let mut n_classes = 0;
        let mut chunk = 0u32;
        let mut lo = 0usize;
        while lo < data.n {
            let hi = (lo + v.batch).min(data.n);
            let key = match v.kind {
                VariantKind::Sc => Some([seed, chunk]),
                VariantKind::Fp => None,
            };
            let (out, _) = self.run_padded(v, data.rows(lo, hi), hi - lo, key)?;
            n_classes = out.n_classes;
            scores.extend_from_slice(&out.scores);
            pred.extend_from_slice(&out.pred);
            margin.extend_from_slice(&out.margin);
            lo = hi;
            chunk += 1;
        }
        Ok(BatchOutputs { scores, pred, margin, batch: data.n, n_classes })
    }

    /// Mean device execute time per batch (µs).
    pub fn mean_execute_us(&self) -> f64 {
        if self.stats.executes == 0 {
            0.0
        } else {
            self.stats.execute_us as f64 / self.stats.executes as f64
        }
    }
}

impl BatchOutputs {
    /// Accuracy against labels.
    pub fn accuracy(&self, labels: &[i32]) -> f64 {
        assert_eq!(labels.len(), self.pred.len());
        if labels.is_empty() {
            return 0.0;
        }
        let ok = self.pred.iter().zip(labels).filter(|(a, b)| a == b).count();
        ok as f64 / labels.len() as f64
    }

    /// One row of scores.
    pub fn score_row(&self, i: usize) -> &[f32] {
        &self.scores[i * self.n_classes..(i + 1) * self.n_classes]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_outputs_accuracy() {
        let o = BatchOutputs { scores: vec![0.0; 6], pred: vec![1, 2, 3], margin: vec![0.1; 3], batch: 3, n_classes: 2 };
        assert!((o.accuracy(&[1, 2, 0]) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn score_row_indexing() {
        let o = BatchOutputs {
            scores: vec![0.1, 0.9, 0.8, 0.2],
            pred: vec![1, 0],
            margin: vec![0.8, 0.6],
            batch: 2,
            n_classes: 2,
        };
        assert_eq!(o.score_row(1), &[0.8, 0.2]);
    }
}
