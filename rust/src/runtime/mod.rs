//! Inference runtime: the [`Backend`] abstraction and its
//! implementations.
//!
//! The cascade, server and experiment layers program against the
//! [`Backend`] trait (compile-by-variant, execute batch →
//! [`BatchOutputs`], dataset/weight lifecycle).  Two substrates
//! implement it:
//!
//! * [`NativeBackend`] ([`native`]) — pure rust over the
//!   [`crate::mlp`]/[`crate::quant`]/[`crate::sc`] modules.  Needs no
//!   `artifacts/` directory (it can synthesise a deterministic fixture
//!   suite, see [`fixture`]) and no external libraries; this is the
//!   default and what CI exercises.
//! * `pjrt::Engine` (behind the `pjrt` cargo feature) — the PJRT CPU
//!   client executing AOT-lowered JAX/Pallas HLO artifacts, the paper's
//!   production path.
//!
//! [`open_backend`] selects between them at runtime (`ari --backend
//! auto|native|pjrt`).

pub mod backend;
pub mod fixture;
pub mod flaky;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use backend::{open_backend, Backend, BackendKind, BatchOutputs, EngineStats, EngineStatsAccum, VariantStats};
pub use flaky::FlakyBackend;
pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::Engine;
