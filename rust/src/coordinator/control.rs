//! Closed-loop threshold control — self-stabilizing resolution
//! (ROADMAP open item 3).
//!
//! The ladder's thresholds are calibrated once, offline, against a
//! fixed split.  This module makes resolution a *runtime* control knob
//! wired through the dispatcher:
//!
//! * **Per-class thresholds** — one `T_i[c]` per stage per predicted
//!   class (Daghero et al., 2204.03431), calibrated on the same split
//!   by [`crate::margin::Calibration::from_pairs_classed`].  With MMax
//!   every per-class threshold is at most the global one, so the mode
//!   preserves calibration-set parity while escalating fewer rows.
//! * **Load adaptation** — the dispatcher feeds queue depth and the
//!   latencies it records into the controller; a *sliding-window* p95
//!   (never the whole-session histogram — see the PR 7 regression this
//!   replaces) plus the depth signal tighten thresholds under pressure
//!   and relax them when idle.  Hysteresis (a hold count plus a dead
//!   band between the tighten and relax bands) makes flapping
//!   impossible under constant load.  The maximum tighten level is a
//!   graded generalisation of the old binary degraded mode.
//! * **Drift** — a windowed monitor over observed stage-0 margins
//!   compares the escalation fraction at the *calibrated* threshold
//!   against the calibration-time baseline; past the tolerance, a
//!   bounded recalibration refreshes the base threshold from the same
//!   sliding window (clamped to a configured distance from the offline
//!   value) without stalling serving.  When the window recovers, the
//!   base snaps back to the offline calibration.
//!
//! Every adaptation step emits a typed
//! [`crate::metrics::ControlEvent`] into the metrics registry, so the
//! loop is observable and replayable.  With every knob at its
//! default-off value the controller returns exactly the ladder's
//! calibrated thresholds and serving is bit-identical to a build
//! without it.
//!
//! The controller is *driven*, never self-timed: it reads no clocks
//! (latencies arrive as values from the dispatcher's existing stamps),
//! takes no locks, and does all its work inline in the dispatch loop —
//! `O(window)` per batch, allocation-free after construction.

use std::collections::VecDeque;

use crate::config::AriConfig;
use crate::metrics::{ControlEvent, MetricsRegistry};

use super::ladder::Ladder;

/// Minimum latency samples before the p95 signal may fire (matches the
/// PR 7 overload detector's warm-up gate).  Windows smaller than this
/// (tests only; config enforces `window >= 16`) gate on a full window
/// instead.
const MIN_P95_SAMPLES: usize = 16;

/// Configuration of the closed-loop threshold controller (the
/// `[control]` config section).  All three mode switches default off:
/// a default policy serves bit-identically to a static-threshold
/// build.
#[derive(Clone, Debug)]
pub struct ControlPolicy {
    /// Serve with per-class stage thresholds instead of one global
    /// `T_i` per stage.
    pub per_class: bool,
    /// Enable load-adaptive tighten/relax with hysteresis.
    pub load_adaptive: bool,
    /// Enable drift detection + online recalibration.
    pub drift: bool,
    /// Sliding latency window length (samples) for the p95 signal.
    pub window: usize,
    /// Window p95 (µs) at or above which load is "high".  0 disables
    /// the latency signal.
    pub p95_high_us: u64,
    /// Window p95 (µs) at or below which load counts as "low".
    pub p95_low_us: u64,
    /// Queue depth at or above which load is "high".  0 disables the
    /// depth signal.
    pub queue_high: usize,
    /// Queue depth at or below which load counts as "low".
    pub queue_low: usize,
    /// Consecutive batches a signal must persist before one step.
    pub hold: u32,
    /// Threshold delta per tighten step.
    pub step: f64,
    /// Maximum tighten level.
    pub max_steps: u32,
    /// Sliding stage-0 margin window length for the drift monitor.
    pub drift_window: usize,
    /// Escalation-fraction deviation from baseline that flags drift.
    pub drift_tolerance: f64,
    /// Minimum fresh margin samples between drift evaluations.
    pub recal_min: usize,
    /// Maximum distance a recalibrated threshold may move from the
    /// offline-calibrated value.
    pub recal_clamp: f64,
}

impl Default for ControlPolicy {
    fn default() -> Self {
        Self::from_config(&AriConfig::default())
    }
}

impl ControlPolicy {
    /// Extract the `[control]` keys from a full configuration.
    pub fn from_config(cfg: &AriConfig) -> Self {
        Self {
            per_class: cfg.control_per_class,
            load_adaptive: cfg.control_load_adaptive,
            drift: cfg.control_drift,
            window: cfg.control_window,
            p95_high_us: cfg.control_p95_high_us,
            p95_low_us: cfg.control_p95_low_us,
            queue_high: cfg.control_queue_high,
            queue_low: cfg.control_queue_low,
            hold: cfg.control_hold,
            step: cfg.control_step,
            max_steps: cfg.control_max_steps,
            drift_window: cfg.control_drift_window,
            drift_tolerance: cfg.control_drift_tolerance,
            recal_min: cfg.control_recal_min,
            recal_clamp: cfg.control_recal_clamp,
        }
    }

    /// Whether any adaptive mode is on.  When false the controller is a
    /// bit-identical pass-through over the ladder's thresholds (it may
    /// still maintain the latency window for the overload detector).
    pub fn enabled(&self) -> bool {
        self.per_class || self.load_adaptive || self.drift
    }
}

/// The closed-loop threshold controller.  Owned by the dispatcher and
/// driven from the dispatch loop: latencies and stage-0 margins stream
/// in per row, [`Controller::end_batch`] advances the control state
/// once per dispatched batch, and [`Controller::threshold`] answers
/// every accept decision.
pub struct Controller {
    policy: ControlPolicy,
    /// Sliding end-to-end latency window (µs), newest at the back.
    lat: VecDeque<u64>,
    /// Sort scratch for the window quantile (reused, never freed).
    lat_scratch: Vec<u64>,
    /// Window p95 as of the last `end_batch` (µs).
    cached_p95: u64,
    /// Current tighten level (0 = calibrated thresholds).
    level: u32,
    high_streak: u32,
    low_streak: u32,
    /// Current per-stage base thresholds (drift recalibration moves
    /// stage 0; the rest stay at calibration).
    base: Vec<f64>,
    /// Immutable offline-calibrated thresholds (recal clamp reference).
    calibrated: Vec<f64>,
    /// Current per-stage per-class thresholds (shifted in lock-step
    /// with `base` under recalibration).
    class_base: Vec<Vec<f64>>,
    /// Offline-calibrated per-class thresholds.
    class_calibrated: Vec<Vec<f64>>,
    /// Calibration-time stage-0 escalation fraction (drift baseline).
    base_esc0: f64,
    /// Sliding window of observed stage-0 margins.
    m0: VecDeque<f32>,
    /// Sort scratch for recalibration quantiles.
    m0_scratch: Vec<f32>,
    /// Fresh margin samples since the last drift evaluation.
    since_eval: usize,
    /// Whether the last drift evaluation exceeded the tolerance.
    drift_active: bool,
    /// Sticky: whether drift was ever flagged this session.
    drifted: bool,
    /// Completed recalibrations.
    recals: u64,
}

impl Controller {
    /// Snapshot a calibrated ladder's thresholds and baselines and
    /// start at level 0 (pass-through).
    pub fn new(policy: ControlPolicy, ladder: &Ladder) -> Self {
        let base: Vec<f64> = ladder.stages.iter().map(|s| s.threshold).collect();
        let class_base: Vec<Vec<f64>> = ladder.stages.iter().map(|s| s.class_thresholds.clone()).collect();
        let base_esc0 = ladder.stages[0].base_escalation;
        Self {
            lat: VecDeque::with_capacity(policy.window),
            lat_scratch: Vec::with_capacity(policy.window),
            cached_p95: 0,
            level: 0,
            high_streak: 0,
            low_streak: 0,
            calibrated: base.clone(),
            class_calibrated: class_base.clone(),
            base,
            class_base,
            base_esc0,
            m0: VecDeque::with_capacity(policy.drift_window),
            m0_scratch: Vec::with_capacity(policy.drift_window),
            since_eval: 0,
            drift_active: false,
            drifted: false,
            recals: 0,
        }
    }

    /// The accept threshold for a row predicted as `pred` at `stage` —
    /// per-class base (when enabled and calibrated for that class)
    /// minus the current tighten offset, clamped at 0.  A non-finite
    /// base (the final stage's accept-everything sentinel) is returned
    /// untouched.  At level 0 with per-class off this is exactly the
    /// ladder's calibrated threshold: bit-identical decisions.
    #[inline]
    pub fn threshold(&self, stage: usize, pred: i32) -> f64 {
        let base = if self.policy.per_class {
            let per = &self.class_base[stage];
            if pred >= 0 && (pred as usize) < per.len() {
                per[pred as usize]
            } else {
                self.base[stage]
            }
        } else {
            self.base[stage]
        };
        if self.level == 0 || !base.is_finite() {
            base
        } else {
            (base - self.level as f64 * self.policy.step).max(0.0)
        }
    }

    /// Record one end-to-end latency sample (µs) into the sliding
    /// window, displacing the oldest once full.
    #[inline]
    pub fn record_latency_us(&mut self, us: u64) {
        if self.lat.len() >= self.policy.window {
            self.lat.pop_front();
        }
        self.lat.push_back(us);
    }

    /// Record one observed stage-0 margin into the drift window.  A
    /// no-op unless drift monitoring is on (zero steady-state cost for
    /// the default configuration).
    #[inline]
    pub fn observe_margin(&mut self, stage: usize, margin: f32) {
        if !self.policy.drift || stage != 0 {
            return;
        }
        if self.m0.len() >= self.policy.drift_window {
            self.m0.pop_front();
        }
        self.m0.push_back(margin);
        self.since_eval += 1;
    }

    /// Advance the control loop once per dispatched batch: refresh the
    /// window p95, then run the load and drift steps for whichever
    /// modes are enabled.  `queue_depth` is the dispatcher's current
    /// backlog (staged batches × batch size plus deferred escalation
    /// queue depth).
    pub fn end_batch(&mut self, queue_depth: usize, metrics: &MetricsRegistry) {
        self.refresh_p95();
        if self.policy.load_adaptive {
            self.step_load(queue_depth, metrics);
        }
        if self.policy.drift {
            self.step_drift(metrics);
        }
    }

    /// Sliding-window p95 latency (µs) as of the last
    /// [`Controller::end_batch`] — the overload detector's signal.
    pub fn window_p95_us(&self) -> u64 {
        self.cached_p95
    }

    /// Latency samples currently in the sliding window.
    pub fn window_len(&self) -> usize {
        self.lat.len()
    }

    /// Whether the p95 signal is warmed up (enough samples to trust).
    pub fn window_warm(&self) -> bool {
        self.lat.len() >= MIN_P95_SAMPLES.min(self.policy.window)
    }

    /// Current tighten level (0 = calibrated thresholds).
    pub fn tighten_level(&self) -> u32 {
        self.level
    }

    /// Whether the monitor currently sees drift.
    pub fn drift_active(&self) -> bool {
        self.drift_active
    }

    /// Whether drift was ever flagged this session.
    pub fn drifted(&self) -> bool {
        self.drifted
    }

    /// Completed online recalibrations.
    pub fn recals(&self) -> u64 {
        self.recals
    }

    /// Current effective global threshold per stage (per-class
    /// variation aside) — what the stats frame reports.
    pub fn effective_threshold(&self, stage: usize) -> f64 {
        let base = self.base[stage];
        if self.level == 0 || !base.is_finite() {
            base
        } else {
            (base - self.level as f64 * self.policy.step).max(0.0)
        }
    }

    fn refresh_p95(&mut self) {
        if self.lat.is_empty() {
            self.cached_p95 = 0;
            return;
        }
        self.lat_scratch.clear();
        self.lat_scratch.extend(self.lat.iter().copied());
        self.lat_scratch.sort_unstable();
        let n = self.lat_scratch.len();
        let idx = ((n as f64 * 0.95).ceil() as usize).clamp(1, n) - 1;
        self.cached_p95 = self.lat_scratch[idx];
    }

    /// One hysteresis step of the load controller.  "High" means any
    /// enabled signal crossed its upper band; "low" means *every*
    /// enabled signal sits at or below its lower band.  Between the
    /// bands both streaks reset — the dead band plus the hold count is
    /// what makes oscillation under constant load impossible.
    fn step_load(&mut self, queue_depth: usize, metrics: &MetricsRegistry) {
        let queue_on = self.policy.queue_high > 0;
        let p95_on = self.policy.p95_high_us > 0;
        let p95_warm = self.window_warm();
        let high = (queue_on && queue_depth >= self.policy.queue_high)
            || (p95_on && p95_warm && self.cached_p95 >= self.policy.p95_high_us);
        let low = !high
            && (!queue_on || queue_depth <= self.policy.queue_low)
            && (!p95_on || !p95_warm || self.cached_p95 <= self.policy.p95_low_us);
        if high {
            self.low_streak = 0;
            self.high_streak += 1;
            if self.high_streak >= self.policy.hold && self.level < self.policy.max_steps {
                self.level += 1;
                self.high_streak = 0;
                metrics.record_control(ControlEvent::Tighten { level: self.level });
            }
        } else if low {
            self.high_streak = 0;
            self.low_streak += 1;
            if self.low_streak >= self.policy.hold && self.level > 0 {
                self.level -= 1;
                self.low_streak = 0;
                metrics.record_control(ControlEvent::Relax { level: self.level });
            }
        } else {
            self.high_streak = 0;
            self.low_streak = 0;
        }
    }

    /// One drift evaluation, rate-limited to every `recal_min` fresh
    /// samples over a full window — the bound on recalibration work.
    fn step_drift(&mut self, metrics: &MetricsRegistry) {
        if self.m0.len() < self.policy.drift_window || self.since_eval < self.policy.recal_min {
            return;
        }
        self.since_eval = 0;
        let n = self.m0.len();
        let t_cal = self.calibrated[0];
        let escalating = self.m0.iter().filter(|&&m| (m as f64) <= t_cal).count();
        let observed = escalating as f64 / n as f64;
        let was_active = self.drift_active;
        self.drift_active = (observed - self.base_esc0).abs() > self.policy.drift_tolerance;
        if self.drift_active {
            self.drifted = true;
            if !was_active {
                metrics.record_control(ControlEvent::Drift { stage: 0, observed, baseline: self.base_esc0 });
            }
            // Refresh: pick the window quantile that restores the
            // calibration-time escalation fraction, clamped to the
            // configured distance from the offline calibration.
            self.m0_scratch.clear();
            self.m0_scratch.extend(self.m0.iter().copied());
            self.m0_scratch.sort_unstable_by(f32::total_cmp);
            let k = ((self.base_esc0 * n as f64).round() as usize).min(n);
            let target = if k == 0 { 0.0 } else { self.m0_scratch[k - 1] as f64 };
            let t_new = target.clamp(t_cal - self.policy.recal_clamp, t_cal + self.policy.recal_clamp).max(0.0);
            if t_new != self.base[0] {
                metrics.record_control(ControlEvent::Recalibrated { stage: 0, from: self.base[0], to: t_new });
                self.shift_stage0(t_new);
                self.recals += 1;
            }
        } else if self.base[0] != t_cal {
            // The window looks calibrated again: snap back to the
            // offline thresholds.
            metrics.record_control(ControlEvent::Recalibrated { stage: 0, from: self.base[0], to: t_cal });
            self.shift_stage0(t_cal);
            self.recals += 1;
        }
    }

    /// Move stage 0's base to `t_new`, carrying the per-class table
    /// with it (same delta from its calibrated values, floored at 0).
    fn shift_stage0(&mut self, t_new: f64) {
        let delta = t_new - self.calibrated[0];
        self.base[0] = t_new;
        for (cur, cal) in self.class_base[0].iter_mut().zip(&self.class_calibrated[0]) {
            *cur = (cal + delta).max(0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Mode, ThresholdPolicy};
    use crate::coordinator::ladder::{LadderSpec, LadderStage};
    use crate::data::{VariantKind, VariantRef};

    fn test_ladder(t0: f64, base_esc0: f64, class_thresholds: Vec<f64>) -> Ladder {
        let spec = LadderSpec {
            dataset: "d".into(),
            mode: Mode::Fp,
            levels: vec![8, 16],
            batch: 32,
            threshold: ThresholdPolicy::MMax,
            seed: 1,
        };
        let stage = |threshold: f64, class_thresholds: Vec<f64>, base_escalation: f64| LadderStage {
            variant: VariantRef {
                dataset: "d".into(),
                kind: VariantKind::Fp,
                level: 8,
                batch: 32,
                file: String::new(),
            },
            threshold,
            calibration: None,
            energy_uj: 1.0,
            class_thresholds,
            base_escalation,
        };
        let stages = vec![stage(t0, class_thresholds, base_esc0), stage(f64::NEG_INFINITY, Vec::new(), 0.0)];
        Ladder { spec, stages }
    }

    fn load_policy(hold: u32, max_steps: u32) -> ControlPolicy {
        ControlPolicy {
            load_adaptive: true,
            queue_high: 100,
            queue_low: 10,
            p95_high_us: 0, // queue signal only: deterministic
            hold,
            max_steps,
            step: 0.1,
            ..ControlPolicy::default()
        }
    }

    /// Disabled controller is a bit-identical pass-through.
    #[test]
    fn passthrough_when_disabled() {
        let ladder = test_ladder(0.4375, 0.2, vec![0.25, 0.4375]);
        let policy = ControlPolicy::default();
        assert!(!policy.enabled());
        let mut ctl = Controller::new(policy, &ladder);
        let m = MetricsRegistry::new();
        for i in 0..100 {
            ctl.record_latency_us(1_000_000 + i);
            ctl.end_batch(10_000, &m);
        }
        assert_eq!(ctl.threshold(0, 0).to_bits(), 0.4375f64.to_bits());
        assert_eq!(ctl.threshold(0, -1).to_bits(), 0.4375f64.to_bits());
        assert_eq!(ctl.threshold(1, 3), f64::NEG_INFINITY);
        assert_eq!(ctl.tighten_level(), 0);
        assert!(m.control_events().is_empty());
    }

    /// Sustained high load tightens exactly to `max_steps` and stays
    /// there; sustained idleness relaxes exactly back to 0 — the cycle
    /// converges at both ends.
    #[test]
    fn tighten_relax_converges() {
        let ladder = test_ladder(0.5, 0.2, Vec::new());
        let mut ctl = Controller::new(load_policy(3, 4), &ladder);
        let m = MetricsRegistry::new();
        for _ in 0..100 {
            ctl.end_batch(500, &m); // far above queue_high
        }
        assert_eq!(ctl.tighten_level(), 4, "saturates at max_steps");
        assert!((ctl.threshold(0, 0) - 0.1).abs() < 1e-12, "0.5 - 4*0.1");
        let tightens = m.control_events().len();
        assert_eq!(tightens, 4, "no further events once saturated");
        for _ in 0..100 {
            ctl.end_batch(0, &m); // fully drained
        }
        assert_eq!(ctl.tighten_level(), 0, "relaxes all the way back");
        assert_eq!(ctl.threshold(0, 0).to_bits(), 0.5f64.to_bits(), "calibrated threshold restored exactly");
        assert_eq!(m.control_events().len(), 8, "4 tightens + 4 relaxes, nothing more");
    }

    /// A constant load anywhere — below, inside, or above the dead band
    /// — cannot make the controller oscillate: after convergence no
    /// further events are emitted.
    #[test]
    fn constant_load_cannot_oscillate() {
        for depth in [0usize, 10, 11, 50, 99, 100, 500] {
            let ladder = test_ladder(0.5, 0.2, Vec::new());
            let mut ctl = Controller::new(load_policy(2, 3), &ladder);
            let m = MetricsRegistry::new();
            for _ in 0..200 {
                ctl.end_batch(depth, &m);
            }
            let settled = m.control_events().len();
            let level = ctl.tighten_level();
            for _ in 0..200 {
                ctl.end_batch(depth, &m);
            }
            assert_eq!(m.control_events().len(), settled, "depth {depth}: events after convergence");
            assert_eq!(ctl.tighten_level(), level, "depth {depth}: level moved under constant load");
            if depth >= 100 {
                assert_eq!(level, 3, "depth {depth} saturates");
            } else if depth <= 10 {
                assert_eq!(level, 0, "depth {depth} stays calibrated");
            } else {
                assert_eq!(level, 0, "dead-band depth {depth} never moves");
            }
        }
    }

    /// The hold count is respected: a pressure blip shorter than `hold`
    /// batches moves nothing.
    #[test]
    fn short_blips_are_ignored() {
        let ladder = test_ladder(0.5, 0.2, Vec::new());
        let mut ctl = Controller::new(load_policy(3, 4), &ladder);
        let m = MetricsRegistry::new();
        for _ in 0..50 {
            ctl.end_batch(500, &m);
            ctl.end_batch(500, &m);
            ctl.end_batch(50, &m); // dead band resets the streak
        }
        assert_eq!(ctl.tighten_level(), 0);
        assert!(m.control_events().is_empty());
    }

    /// The p95 signal uses the *sliding window*: a historical spike
    /// scrolls out and the controller relaxes — the regression the
    /// whole-session histogram could never pass.
    #[test]
    fn p95_window_forgets_old_spikes() {
        let ladder = test_ladder(0.5, 0.2, Vec::new());
        let policy = ControlPolicy {
            load_adaptive: true,
            queue_high: 0, // p95 signal only
            p95_high_us: 10_000,
            p95_low_us: 1_000,
            window: 32,
            hold: 2,
            max_steps: 2,
            ..ControlPolicy::default()
        };
        let mut ctl = Controller::new(policy, &ladder);
        let m = MetricsRegistry::new();
        for _ in 0..32 {
            ctl.record_latency_us(50_000);
        }
        for _ in 0..4 {
            ctl.end_batch(0, &m);
        }
        assert!(ctl.tighten_level() > 0, "spike tightens");
        // 32 fast samples displace the whole spike from the window.
        for _ in 0..32 {
            ctl.record_latency_us(100);
        }
        for _ in 0..8 {
            ctl.end_batch(0, &m);
        }
        assert_eq!(ctl.tighten_level(), 0, "window p95 must decay once the spike scrolls out");
        assert_eq!(ctl.window_p95_us(), 100);
    }

    /// Drift detection + recalibration: a shifted margin stream flags
    /// drift once (rising edge), refreshes the stage-0 threshold toward
    /// the window quantile within the clamp, and snaps back to the
    /// offline calibration when the stream recovers.
    #[test]
    fn drift_detects_recalibrates_and_recovers() {
        let ladder = test_ladder(0.5, 0.5, vec![0.4, 0.5]);
        let policy = ControlPolicy {
            drift: true,
            per_class: true,
            drift_window: 64,
            drift_tolerance: 0.2,
            recal_min: 16,
            recal_clamp: 0.3,
            ..ControlPolicy::default()
        };
        let mut ctl = Controller::new(policy, &ladder);
        let m = MetricsRegistry::new();
        // Calibrated world: margins uniform over (0,1)-ish, half below
        // T=0.5 — matches base_esc0 = 0.5, no drift.
        for i in 0..128 {
            ctl.observe_margin(0, (i % 100) as f32 / 100.0);
            if i % 8 == 7 {
                ctl.end_batch(0, &m);
            }
        }
        assert!(!ctl.drift_active());
        assert_eq!(ctl.recals(), 0);
        // Drifted world: margins collapse toward 0 — nearly everything
        // would escalate at the calibrated threshold.
        for i in 0..128 {
            ctl.observe_margin(0, 0.05 + (i % 10) as f32 / 1000.0);
            if i % 8 == 7 {
                ctl.end_batch(0, &m);
            }
        }
        assert!(ctl.drift_active());
        assert!(ctl.drifted());
        assert!(ctl.recals() >= 1);
        let events = m.control_events();
        assert!(
            events.iter().any(|e| matches!(e, ControlEvent::Drift { stage: 0, .. })),
            "drift event emitted: {events:?}"
        );
        let t = ctl.threshold(0, 5); // out-of-range class: global base
        assert!(t < 0.5, "threshold moved down toward the drifted quantile, got {t}");
        assert!(t >= 0.5 - 0.3 - 1e-12, "clamped to recal_clamp below calibration, got {t}");
        // Per-class table shifted in lock-step (same delta, floored).
        let delta = t - 0.5;
        assert!((ctl.threshold(0, 0) - (0.4 + delta).max(0.0)).abs() < 1e-12);
        // Recovery: the stream returns to the calibrated distribution.
        for i in 0..128 {
            ctl.observe_margin(0, (i % 100) as f32 / 100.0);
            if i % 8 == 7 {
                ctl.end_batch(0, &m);
            }
        }
        assert!(!ctl.drift_active());
        assert_eq!(ctl.threshold(0, 5).to_bits(), 0.5f64.to_bits(), "offline calibration restored exactly");
        assert_eq!(ctl.threshold(0, 0).to_bits(), 0.4f64.to_bits());
    }

    /// Per-class mode keys the base threshold on the predicted class
    /// and composes with the tighten offset.
    #[test]
    fn per_class_thresholds_compose_with_tighten() {
        let ladder = test_ladder(0.5, 0.2, vec![0.2, 0.5, 0.35]);
        let policy = ControlPolicy { per_class: true, ..load_policy(1, 2) };
        let mut ctl = Controller::new(policy, &ladder);
        let m = MetricsRegistry::new();
        assert_eq!(ctl.threshold(0, 0).to_bits(), 0.2f64.to_bits());
        assert_eq!(ctl.threshold(0, 2).to_bits(), 0.35f64.to_bits());
        assert_eq!(ctl.threshold(0, 9).to_bits(), 0.5f64.to_bits(), "unknown class falls back to global");
        ctl.end_batch(500, &m); // hold=1: tightens immediately
        assert_eq!(ctl.tighten_level(), 1);
        assert!((ctl.threshold(0, 0) - 0.1).abs() < 1e-12);
        assert!((ctl.threshold(0, 2) - 0.25).abs() < 1e-12);
        assert_eq!(ctl.threshold(1, 0), f64::NEG_INFINITY, "final stage still accepts everything");
    }

    /// Tightening can never push a threshold below 0 or disturb the
    /// final stage's accept-everything sentinel.
    #[test]
    fn tighten_clamps_at_zero() {
        let ladder = test_ladder(0.15, 0.2, Vec::new());
        let mut ctl = Controller::new(load_policy(1, 4), &ladder);
        let m = MetricsRegistry::new();
        for _ in 0..8 {
            ctl.end_batch(500, &m);
        }
        assert_eq!(ctl.tighten_level(), 4);
        assert_eq!(ctl.threshold(0, 0), 0.0, "0.15 - 0.4 clamps at 0");
        assert_eq!(ctl.threshold(1, 0), f64::NEG_INFINITY);
    }
}
