//! Dynamic batcher: size + deadline policy over an incoming request
//! stream.
//!
//! Requests accumulate until either `max_batch` rows are waiting or the
//! oldest request has waited `max_wait`; the batch then dispatches.  This
//! is the standard serving trade-off (throughput vs tail latency) — the
//! policy is exercised by `benches/bench_cascade.rs` and the batching
//! ablation in EXPERIMENTS.md.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherPolicy {
    /// Fire as soon as this many requests are waiting.
    pub max_batch: usize,
    /// Fire when the oldest request has waited this long.
    pub max_wait: Duration,
}

impl BatcherPolicy {
    /// Validate and build a policy (`max_batch` must be > 0).
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch > 0);
        Self { max_batch, max_wait }
    }
}

/// One pending request.
#[derive(Debug)]
pub struct Pending<T> {
    /// The queued request.
    pub payload: T,
    /// When it entered the queue.
    pub enqueued: Instant,
}

/// A drained batch.
#[derive(Debug)]
pub struct Batch<T> {
    /// The drained requests, FIFO order.
    pub items: Vec<Pending<T>>,
    /// Why the batch fired.
    pub reason: FireReason,
}

/// Why a batch was released.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FireReason {
    /// `max_batch` requests were waiting.
    Size,
    /// The oldest request hit `max_wait`.
    Deadline,
    /// Unconditional shutdown flush.
    Drain,
}

/// The queue.  Single-consumer; producers push through a channel and the
/// coordinator thread owns the batcher (PJRT is not Send — see runtime).
pub struct Batcher<T> {
    policy: BatcherPolicy,
    queue: VecDeque<Pending<T>>,
}

impl<T> Batcher<T> {
    /// Empty queue under a policy.
    pub fn new(policy: BatcherPolicy) -> Self {
        Self { policy, queue: VecDeque::new() }
    }

    /// Enqueue a request now.
    pub fn push(&mut self, payload: T) {
        // ari-lint: allow(clock-discipline): convenience enqueue for tests and one-shot
        // callers; the serving loop threads its single per-iteration read via `push_at`.
        self.queue.push_back(Pending { payload, enqueued: Instant::now() });
    }

    /// Enqueue a request with an explicit enqueue time.
    pub fn push_at(&mut self, payload: T, enqueued: Instant) {
        self.queue.push_back(Pending { payload, enqueued });
    }

    /// Requests currently waiting.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// Whether the queue is empty.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Would a batch fire now?
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        match self.queue.front() {
            Some(p) => now.duration_since(p.enqueued) >= self.policy.max_wait,
            None => false,
        }
    }

    /// Time until the deadline of the oldest request (None if empty).
    /// The server loop uses this as its channel-recv timeout — no busy
    /// polling.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|p| {
            let waited = now.duration_since(p.enqueued);
            self.policy.max_wait.saturating_sub(waited)
        })
    }

    /// Drain a batch if the policy says so.
    pub fn try_fire(&mut self, now: Instant) -> Option<Batch<T>> {
        let mut items = Vec::new();
        self.try_fire_into(now, &mut items).map(|reason| Batch { items, reason })
    }

    /// Allocation-free twin of [`Batcher::try_fire`]: the due batch (if
    /// any) is drained into `out` (cleared first) and the fire reason
    /// returned.  The pipelined server calls this with recycled staging
    /// buffers; firing decisions are identical to `try_fire` at equal
    /// `now`.
    pub fn try_fire_into(&mut self, now: Instant, out: &mut Vec<Pending<T>>) -> Option<FireReason> {
        if self.queue.len() >= self.policy.max_batch {
            out.clear();
            out.extend(self.queue.drain(..self.policy.max_batch));
            return Some(FireReason::Size);
        }
        if self.ready(now) {
            out.clear();
            out.extend(self.queue.drain(..));
            return Some(FireReason::Deadline);
        }
        None
    }

    /// Unconditionally drain up to `max_batch` requests (shutdown path).
    /// Call repeatedly until `None` to flush everything — chunking keeps
    /// every yielded batch dispatchable at the compiled batch size (a
    /// full drain used to return arbitrarily large batches, underflowing
    /// the server's padding accounting and exceeding `run_padded`'s
    /// `n <= batch` contract).
    pub fn drain(&mut self) -> Option<Batch<T>> {
        let mut items = Vec::new();
        self.drain_into(&mut items).map(|reason| Batch { items, reason })
    }

    /// Allocation-free twin of [`Batcher::drain`].
    pub fn drain_into(&mut self, out: &mut Vec<Pending<T>>) -> Option<FireReason> {
        if self.queue.is_empty() {
            return None;
        }
        // `unchunked-drain` reintroduces the historical unchunked drain
        // (arbitrarily large shutdown batches) so the model suite can
        // prove its chunk-bound invariant catches it.  Test-only; the
        // fault switch is compiled out of release builds.
        let take = if crate::util::sim::fault("unchunked-drain") {
            self.queue.len()
        } else {
            self.queue.len().min(self.policy.max_batch)
        };
        out.clear();
        out.extend(self.queue.drain(..take));
        Some(FireReason::Drain)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(n: usize, ms: u64) -> BatcherPolicy {
        BatcherPolicy::new(n, Duration::from_millis(ms))
    }

    #[test]
    fn fires_on_size() {
        let mut b = Batcher::new(policy(3, 1000));
        let now = Instant::now();
        b.push(1);
        b.push(2);
        assert!(b.try_fire(now).is_none());
        b.push(3);
        let batch = b.try_fire(now).unwrap();
        assert_eq!(batch.reason, FireReason::Size);
        assert_eq!(batch.items.len(), 3);
        assert!(b.is_empty());
    }

    #[test]
    fn fires_on_deadline() {
        let mut b = Batcher::new(policy(10, 5));
        let t0 = Instant::now();
        b.push_at(1, t0);
        b.push_at(2, t0);
        assert!(b.try_fire(t0).is_none());
        let later = t0 + Duration::from_millis(6);
        let batch = b.try_fire(later).unwrap();
        assert_eq!(batch.reason, FireReason::Deadline);
        assert_eq!(batch.items.len(), 2);
    }

    #[test]
    fn size_cap_leaves_remainder() {
        let mut b = Batcher::new(policy(2, 1000));
        for i in 0..5 {
            b.push(i);
        }
        let now = Instant::now();
        assert_eq!(b.try_fire(now).unwrap().items.len(), 2);
        assert_eq!(b.try_fire(now).unwrap().items.len(), 2);
        assert_eq!(b.len(), 1);
        assert!(b.try_fire(now).is_none()); // remainder waits for deadline
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(policy(3, 1000));
        for i in 0..3 {
            b.push(i);
        }
        let batch = b.try_fire(Instant::now()).unwrap();
        let vals: Vec<i32> = batch.items.iter().map(|p| p.payload).collect();
        assert_eq!(vals, vec![0, 1, 2]);
    }

    #[test]
    fn next_deadline_counts_down() {
        let mut b = Batcher::new(policy(10, 100));
        let t0 = Instant::now();
        b.push_at(1, t0);
        let d = b.next_deadline(t0 + Duration::from_millis(40)).unwrap();
        assert!(d <= Duration::from_millis(60));
        assert!(d >= Duration::from_millis(40));
        assert!(b.next_deadline(t0 + Duration::from_millis(200)).unwrap().is_zero());
    }

    #[test]
    fn drain_flushes_all() {
        let mut b = Batcher::new(policy(10, 1000));
        b.push(1);
        b.push(2);
        let batch = b.drain().unwrap();
        assert_eq!(batch.reason, FireReason::Drain);
        assert_eq!(batch.items.len(), 2);
        assert!(b.drain().is_none());
    }

    /// Regression: flooding the queue far past `max_batch` and then
    /// draining must yield chunks no larger than `max_batch` (the old
    /// drain returned everything at once, which underflowed the server's
    /// `batch - n` padding arithmetic and violated `run_padded`'s
    /// `n <= batch` contract on shutdown).
    #[test]
    fn flood_then_drain_chunks_at_max_batch() {
        let mut b = Batcher::new(policy(8, 1_000_000));
        for i in 0..100 {
            b.push(i);
        }
        let mut seen = Vec::new();
        while let Some(batch) = b.drain() {
            assert!(batch.items.len() <= 8, "drain yielded {} > max_batch", batch.items.len());
            assert_eq!(batch.reason, FireReason::Drain);
            seen.extend(batch.items.iter().map(|p| p.payload));
        }
        assert_eq!(seen, (0..100).collect::<Vec<i32>>());
        assert!(b.is_empty());
    }

    /// The `_into` twins must make identical firing decisions to the
    /// allocating paths at equal timestamps — one timestamp per server
    /// iteration threads through `push_at`/`try_fire_into`, and this
    /// pins that deadline behaviour is unchanged by the rework.
    #[test]
    fn fire_into_matches_try_fire_decisions() {
        let t0 = Instant::now();
        for wait_ms in [0u64, 3, 6] {
            let mut a = Batcher::new(policy(3, 5));
            let mut b = Batcher::new(policy(3, 5));
            for i in 0..2 {
                a.push_at(i, t0);
                b.push_at(i, t0);
            }
            let now = t0 + Duration::from_millis(wait_ms);
            let got_a = a.try_fire(now);
            let mut items = Vec::new();
            let got_b = b.try_fire_into(now, &mut items);
            match (got_a, got_b) {
                (None, None) => assert!(items.is_empty()),
                (Some(batch), Some(reason)) => {
                    assert_eq!(batch.reason, reason, "wait={wait_ms}ms");
                    let av: Vec<i32> = batch.items.iter().map(|p| p.payload).collect();
                    let bv: Vec<i32> = items.iter().map(|p| p.payload).collect();
                    assert_eq!(av, bv);
                }
                (a, b) => panic!("decision mismatch at wait={wait_ms}ms: {a:?} vs {b:?}"),
            }
        }
        // Size-based firing agrees too, and leaves the same remainder.
        let mut a = Batcher::new(policy(2, 1000));
        let mut b = Batcher::new(policy(2, 1000));
        for i in 0..5 {
            a.push_at(i, t0);
            b.push_at(i, t0);
        }
        let mut items = Vec::new();
        assert_eq!(b.try_fire_into(t0, &mut items), Some(FireReason::Size));
        assert_eq!(a.try_fire(t0).unwrap().items.len(), items.len());
        assert_eq!(a.len(), b.len());
    }

    /// Recycled staging buffers keep their capacity and are cleared per
    /// fire; drained chunks respect `max_batch` like `drain`.
    #[test]
    fn into_buffers_are_recycled_and_chunked() {
        let mut b = Batcher::new(policy(4, 1_000_000));
        for i in 0..10 {
            b.push(i);
        }
        let mut buf: Vec<Pending<i32>> = Vec::new();
        let mut seen = Vec::new();
        while let Some(reason) = b.drain_into(&mut buf) {
            assert_eq!(reason, FireReason::Drain);
            assert!(buf.len() <= 4);
            seen.extend(buf.iter().map(|p| p.payload));
        }
        assert_eq!(seen, (0..10).collect::<Vec<i32>>());
        assert!(buf.capacity() >= 2, "buffer reused across drains");
    }

    /// Property: no request is ever lost or duplicated across an
    /// arbitrary interleaving of pushes and fires.
    #[test]
    fn conservation_property() {
        crate::util::proptest::run(crate::util::proptest::Config::cases(64), |rng| {
            let cap = 1 + rng.below(8) as usize;
            let mut b = Batcher::new(policy(cap, 1));
            let total = rng.below(200) as usize;
            let mut seen = Vec::new();
            let mut pushed = 0usize;
            let t0 = Instant::now();
            while pushed < total || !b.is_empty() {
                if pushed < total && rng.next_f64() < 0.6 {
                    b.push_at(pushed, t0);
                    pushed += 1;
                } else {
                    // time always "past deadline" to force firing
                    if let Some(batch) = b.try_fire(t0 + Duration::from_millis(5)) {
                        seen.extend(batch.items.iter().map(|p| p.payload));
                    }
                }
            }
            assert_eq!(seen.len(), total);
            for (i, &v) in seen.iter().enumerate() {
                assert_eq!(v, i, "order violated");
            }
        });
    }
}
