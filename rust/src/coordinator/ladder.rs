//! N-level resolution ladders — the generalisation of the paper's
//! two-tier reduced→full cascade.
//!
//! The paper evaluates one operating point: a single reduced model in
//! front of the full model.  Multi-stage big/little cascades with a
//! confidence gate per stage (Daghero et al., arXiv 2204.03431) and the
//! precision-as-a-ladder framing of the resource-efficiency survey
//! (arXiv 2001.03048) suggest the richer design space this module
//! implements: a [`Ladder`] of N calibrated stages, e.g. FP8 → FP12 →
//! FP16 or SC L=128 → 512 → 2048.
//!
//! * **Calibration** — each non-final stage `i` is calibrated against
//!   the *final* stage on the calibration split, exactly like the
//!   paper's §III-C pair: collect stage-`i` margins of elements whose
//!   predicted class differs from the final model's, and derive `T_i`
//!   from the configured [`ThresholdPolicy`] (reusing
//!   [`crate::margin::Calibration`]).
//! * **Inference** — a batch runs on stage 0; rows whose margin clears
//!   `T_0` stop there, the rest are gathered and escalated to stage 1,
//!   and so on down the ladder (the final stage accepts everything).
//!   Per-stage energy accounting extends the paper's eq. (1) to
//!   `E = Σ_i f_i · E_i` where `f_i` is the fraction of rows that
//!   executed stage `i`.
//!
//! The 2-level ladder is bit-compatible with the original
//! [`crate::coordinator::Cascade`] (which is now a thin wrapper over
//! this type): calibration runs use the same seeds, and SC keys use the
//! same per-stage salt, so PR 2's cascade outputs are reproduced
//! exactly — pinned by `tests/ladder.rs`.

use crate::config::{AriConfig, Mode, ThresholdPolicy};
use crate::data::{EvalData, VariantRef};
use crate::energy::EnergyModel;
use crate::margin::{accepts, Calibration};
use crate::runtime::{Backend, BatchOutputs};

/// Static description of an N-level ladder (what to build from the
/// manifest).
#[derive(Clone, Debug)]
pub struct LadderSpec {
    /// Dataset to serve.
    pub dataset: String,
    /// Resolution family.
    pub mode: Mode,
    /// Stage levels, ascending; the last entry is the full model (FP
    /// bit widths or SC sequence lengths).  The degenerate
    /// reduced == full pair is allowed as an always-full baseline.
    pub levels: Vec<usize>,
    /// Batch size every stage variant is compiled at.
    pub batch: usize,
    /// Threshold selection policy applied to every non-final stage.
    pub threshold: ThresholdPolicy,
    /// SC key seed (ignored for FP).
    pub seed: u32,
}

impl LadderSpec {
    /// Derive a spec from the server configuration
    /// ([`AriConfig::ladder_levels`] falls back to the 2-level
    /// reduced/full pair when no explicit ladder is configured).
    pub fn from_config(cfg: &AriConfig) -> Self {
        Self {
            dataset: cfg.dataset.clone(),
            mode: cfg.mode,
            levels: cfg.ladder_levels(),
            batch: cfg.batch_size,
            threshold: cfg.threshold,
            seed: cfg.seed as u32,
        }
    }
}

/// One calibrated stage of a ladder.
#[derive(Clone, Debug)]
pub struct LadderStage {
    /// The compiled variant this stage executes.
    pub variant: VariantRef,
    /// The calibrated margin threshold `T_i`; rows with margin `> T_i`
    /// are accepted at this stage.  The final stage accepts everything
    /// (`f64::NEG_INFINITY`).
    pub threshold: f64,
    /// Calibration statistics `T_i` was derived from (None for the
    /// final stage, which is the calibration reference).
    pub calibration: Option<Calibration>,
    /// Modelled energy per inference at this stage (µJ).
    pub energy_uj: f64,
    /// Per-class thresholds `T_i[c]` keyed by this stage's predicted
    /// class, calibrated on the same split (Daghero et al.,
    /// 2204.03431).  Empty for the final stage.  Only consulted when
    /// `control.per_class` is on — the global `threshold` stays the
    /// bit-identical default.
    pub class_thresholds: Vec<f64>,
    /// Calibration-time escalation fraction at `threshold` over all
    /// calibration elements — the drift monitor's baseline (0.0 for the
    /// final stage).
    pub base_escalation: f64,
}

/// Result of one batch run through a ladder.
#[derive(Clone, Debug)]
pub struct LadderBatch {
    /// Final predictions (stage 0, overwritten by deeper stages where
    /// escalated).
    pub pred: Vec<i32>,
    /// Final margins (same overwrite rule).
    pub margin: Vec<f32>,
    /// Per-row index of the stage that produced the final prediction.
    pub stage: Vec<usize>,
    /// Rows that *executed* each stage (`stage_counts[0]` is the batch
    /// size; deeper entries shrink as rows are accepted).
    pub stage_counts: Vec<usize>,
    /// Modelled energy for the batch (µJ): `Σ_i stage_counts[i] · E_i`.
    pub energy_uj: f64,
    /// Stage-0 predictions before any overwrite — kept for analysis.
    pub first_pred: Vec<i32>,
    /// Stage-0 margins before any overwrite.  Every row carries one
    /// (escalated rows overwrite `margin` with the deeper stage's), so
    /// the drift monitor sees the *unbiased* stage-0 margin stream.
    pub first_margin: Vec<f32>,
    /// Classes per row, as reported by the backend outputs.
    pub n_classes: usize,
}

impl LadderBatch {
    /// An empty result, ready to be filled by
    /// [`Ladder::infer_batch_into`] — the serving loop keeps one and
    /// reuses its buffers across batches.
    pub fn empty() -> Self {
        Self {
            pred: Vec::new(),
            margin: Vec::new(),
            stage: Vec::new(),
            stage_counts: Vec::new(),
            energy_uj: 0.0,
            first_pred: Vec::new(),
            first_margin: Vec::new(),
            n_classes: 0,
        }
    }

    /// Fraction of rows that executed each stage (`f_i` in the energy
    /// accounting `E = Σ_i f_i · E_i`).
    pub fn stage_fractions(&self) -> Vec<f64> {
        let n = self.pred.len().max(1) as f64;
        self.stage_counts.iter().map(|&c| c as f64 / n).collect()
    }

    /// Fraction of rows that escalated past stage 0.
    pub fn escalation_fraction(&self) -> f64 {
        if self.pred.is_empty() {
            return 0.0;
        }
        self.stage.iter().filter(|&&s| s > 0).count() as f64 / self.pred.len() as f64
    }
}

/// Reusable gather/scatter/padding scratch for the ladder's serving hot
/// path ([`Ladder::infer_batch_into`], [`Ladder::run_stage_scratch`]).
/// Buffer capacities grow to the largest batch seen and persist, so a
/// steady-state serving loop allocates nothing per dispatched batch.
#[derive(Default)]
pub struct LadderScratch {
    /// Escalated rows gathered contiguously for a deeper stage.
    gathered: Vec<f32>,
    /// Zero-padded staging when a partial batch runs on a compiled
    /// full-batch variant (the scratch twin of `Backend::run_padded`).
    padded: Vec<f32>,
    /// Row indices still escalating after the current stage.
    rows: Vec<usize>,
    /// Row indices that will escalate past the next stage.
    next_rows: Vec<usize>,
}

impl LadderScratch {
    /// Empty scratch (buffers grow on first use).
    pub fn new() -> Self {
        Self::default()
    }
}

/// A calibrated, servable N-level ladder.
pub struct Ladder {
    /// The spec this ladder was built from.
    pub spec: LadderSpec,
    /// The calibrated stages, ascending resolution; the last is the
    /// full model.
    pub stages: Vec<LadderStage>,
}

impl Ladder {
    /// Build and calibrate: runs every stage over rows [0, n_calib) of
    /// the eval split and derives each non-final stage's threshold
    /// against the final stage's predictions.
    pub fn calibrate(
        engine: &mut dyn Backend,
        spec: LadderSpec,
        data: &EvalData,
        n_calib: usize,
    ) -> crate::Result<Self> {
        anyhow::ensure!(spec.levels.len() >= 2, "a ladder needs at least 2 levels, got {:?}", spec.levels);
        // Non-decreasing: strict ascent is the useful shape, but the
        // degenerate reduced == full cascade is a supported baseline
        // ("always-full": nothing ever escalates at a fixed T < 0).
        anyhow::ensure!(
            spec.levels.windows(2).all(|w| w[0] <= w[1]),
            "ladder levels must be ascending (reduced -> full), got {:?}",
            spec.levels
        );
        anyhow::ensure!(n_calib > 0 && n_calib <= data.n, "bad calibration size {n_calib}");
        let kind = spec.mode.kind();
        let mut variants: Vec<VariantRef> = Vec::with_capacity(spec.levels.len());
        for &level in &spec.levels {
            variants.push(engine.manifest().variant(&spec.dataset, kind, level, spec.batch)?.clone());
        }
        let calib_slice = EvalData {
            x: data.rows(0, n_calib).to_vec(),
            y: data.y[..n_calib].to_vec(),
            n: n_calib,
            input_dim: data.input_dim,
        };
        // The final stage is the calibration reference.  Seeds follow
        // the original cascade's scheme (full = seed, stage i =
        // seed + i + 1) so the 2-level ladder is bit-identical to it.
        let full_out = engine.run_dataset(variants.last().unwrap(), &calib_slice, spec.seed)?;

        let dims = engine.weights(&spec.dataset)?.dims();
        let energy = EnergyModel::for_dims(&dims);
        let n_stages = spec.levels.len();
        let mut stages = Vec::with_capacity(n_stages);
        for (i, variant) in variants.into_iter().enumerate() {
            let energy_uj = match spec.mode {
                Mode::Fp => energy.fp_energy(crate::quant::FpFormat::fp(spec.levels[i] as u32)),
                Mode::Sc => energy.sc_energy(crate::sc::ScConfig::new(spec.levels[i])),
            };
            if i + 1 == n_stages {
                stages.push(LadderStage {
                    variant,
                    threshold: f64::NEG_INFINITY,
                    calibration: None,
                    energy_uj,
                    class_thresholds: Vec::new(),
                    base_escalation: 0.0,
                });
            } else {
                let out = engine.run_dataset(&variant, &calib_slice, spec.seed.wrapping_add(i as u32 + 1))?;
                let calibration =
                    Calibration::from_pairs_classed(&full_out.pred, &out.pred, &out.margin, full_out.n_classes);
                let threshold = calibration.threshold(spec.threshold);
                let class_thresholds = calibration.class_thresholds(spec.threshold, threshold);
                let base_escalation = Calibration::escalation_fraction(&out.margin, threshold);
                stages.push(LadderStage {
                    variant,
                    threshold,
                    calibration: Some(calibration),
                    energy_uj,
                    class_thresholds,
                    base_escalation,
                });
            }
        }
        Ok(Self { spec, stages })
    }

    /// Number of stages in the ladder.
    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Modelled energy per inference of the final (full) stage (µJ).
    pub fn e_full(&self) -> f64 {
        self.stages.last().unwrap().energy_uj
    }

    /// SC key for one batch of a stage (None for FP).  Stage 0 uses the
    /// raw seed and each deeper stage XORs a per-stage salt — stage 1's
    /// salt is `0x5A5A_5A5A`, keeping the 2-level ladder bit-compatible
    /// with the original cascade while decorrelating N stages.
    pub fn key_for(&self, stage: usize, key_seed: u32) -> Option<[u32; 2]> {
        match self.spec.mode {
            Mode::Sc => Some([self.spec.seed ^ (stage as u32).wrapping_mul(0x5A5A_5A5A), key_seed]),
            Mode::Fp => None,
        }
    }

    /// Run `n` rows on one stage only (used by the server's deferred
    /// escalation queues, which manage their own gather/scatter).
    pub fn run_stage(
        &self,
        engine: &mut dyn Backend,
        stage: usize,
        x: &[f32],
        n: usize,
        key_seed: u32,
    ) -> crate::Result<BatchOutputs> {
        Ok(engine.run_padded(&self.stages[stage].variant, x, n, self.key_for(stage, key_seed))?.0)
    }

    /// [`Ladder::run_stage`] for the allocation-free serving path: any
    /// zero-padding to the compiled batch is staged in `scratch.padded`
    /// instead of a fresh vector, and output storage comes from the
    /// engine's recycle pool when the caller returns outputs via
    /// `Backend::recycle_outputs`.  Bit-identical to `run_stage` (same
    /// zero padding, same key derivation, outputs truncated to `n`).
    /// Also returns the padding waste (unused batch slots) for the
    /// metrics.
    pub fn run_stage_scratch(
        &self,
        engine: &mut dyn Backend,
        stage: usize,
        x: &[f32],
        n: usize,
        key_seed: u32,
        scratch: &mut LadderScratch,
    ) -> crate::Result<(BatchOutputs, usize)> {
        let v = &self.stages[stage].variant;
        // Same validation as `Backend::run_padded` (manifest-derived
        // width, exact length) so the two padding paths reject the same
        // inputs with the same precision.
        let input_dim = engine.manifest().dataset(&v.dataset)?.input_dim;
        anyhow::ensure!(n > 0 && n <= v.batch, "n={n} out of range for batch {}", v.batch);
        anyhow::ensure!(x.len() == n * input_dim, "input length mismatch");
        let key = self.key_for(stage, key_seed);
        let waste = v.batch - n;
        if waste == 0 {
            return Ok((engine.execute(v, x, key)?, 0));
        }
        scratch.padded.clear();
        scratch.padded.resize(v.batch * input_dim, 0.0);
        scratch.padded[..x.len()].copy_from_slice(x);
        let mut out = engine.execute(v, &scratch.padded, key)?;
        out.scores.truncate(n * out.n_classes);
        out.pred.truncate(n);
        out.margin.truncate(n);
        out.batch = n;
        Ok((out, waste))
    }

    /// Serve one batch of `n` rows down the ladder.  `key_seed` feeds
    /// SC key derivation (ignored for FP); every stage of this call
    /// shares it (stages are decorrelated by the per-stage salt).
    pub fn infer_batch(
        &self,
        engine: &mut dyn Backend,
        x: &[f32],
        n: usize,
        key_seed: u32,
    ) -> crate::Result<LadderBatch> {
        let mut out = LadderBatch::empty();
        self.infer_batch_into(engine, x, n, key_seed, &mut LadderScratch::new(), &mut out)?;
        Ok(out)
    }

    /// [`Ladder::infer_batch`] writing into a reusable result and
    /// gather/scatter scratch — the serving loop's allocation-free
    /// path.  `out`'s buffers are cleared and refilled; outputs are
    /// bit-identical to [`Ladder::infer_batch`] (same chunking, same
    /// zero padding, same keys).
    pub fn infer_batch_into(
        &self,
        engine: &mut dyn Backend,
        x: &[f32],
        n: usize,
        key_seed: u32,
        scratch: &mut LadderScratch,
        out: &mut LadderBatch,
    ) -> crate::Result<()> {
        self.infer_batch_with(engine, x, n, key_seed, scratch, out, &|s, _| self.stages[s].threshold)
    }

    /// [`Ladder::infer_batch_into`] with an injectable accept threshold:
    /// `thr(stage, pred)` supplies the threshold each row's margin is
    /// tested against (the closed-loop controller routes per-class and
    /// load-tightened values through here).  With the static closure
    /// `|s, _| stages[s].threshold` the decisions — and hence the
    /// outputs — are bit-identical to [`Ladder::infer_batch_into`].
    pub fn infer_batch_with(
        &self,
        engine: &mut dyn Backend,
        x: &[f32],
        n: usize,
        key_seed: u32,
        scratch: &mut LadderScratch,
        out: &mut LadderBatch,
        thr: &dyn Fn(usize, i32) -> f64,
    ) -> crate::Result<()> {
        let (first, _) = self.run_stage_scratch(engine, 0, x, n, key_seed, scratch)?;
        out.pred.clear();
        out.pred.extend_from_slice(&first.pred);
        out.margin.clear();
        out.margin.extend_from_slice(&first.margin);
        out.first_pred.clear();
        out.first_pred.extend_from_slice(&first.pred);
        out.first_margin.clear();
        out.first_margin.extend_from_slice(&first.margin);
        out.stage.clear();
        out.stage.resize(n, 0);
        out.stage_counts.clear();
        out.stage_counts.resize(self.stages.len(), 0);
        out.stage_counts[0] = n;
        out.n_classes = first.n_classes;
        let input_dim = x.len() / n;
        // The index vectors are moved out of the scratch for the loop
        // (so `run_stage_scratch` can borrow the scratch mutably) and
        // moved back at the end — no allocation either way.
        let mut rows = std::mem::take(&mut scratch.rows);
        let mut next_rows = std::mem::take(&mut scratch.next_rows);
        let mut gathered = std::mem::take(&mut scratch.gathered);
        rows.clear();
        rows.extend((0..n).filter(|&i| !accepts(first.margin[i], thr(0, first.pred[i]))));
        engine.recycle_outputs(first);
        let mut result = Ok(());
        'stages: for s in 1..self.stages.len() {
            if rows.is_empty() {
                break;
            }
            out.stage_counts[s] = rows.len();
            next_rows.clear();
            // Gather escalated rows (they may exceed one stage batch).
            for chunk in rows.chunks(self.stages[s].variant.batch) {
                gathered.clear();
                for &i in chunk {
                    gathered.extend_from_slice(&x[i * input_dim..(i + 1) * input_dim]);
                }
                let stage_out = match self.run_stage_scratch(engine, s, &gathered, chunk.len(), key_seed, scratch) {
                    Ok((o, _)) => o,
                    Err(e) => {
                        result = Err(e);
                        break 'stages;
                    }
                };
                for (j, &i) in chunk.iter().enumerate() {
                    out.pred[i] = stage_out.pred[j];
                    out.margin[i] = stage_out.margin[j];
                    out.stage[i] = s;
                    if s + 1 < self.stages.len() && !accepts(stage_out.margin[j], thr(s, stage_out.pred[j])) {
                        next_rows.push(i);
                    }
                }
                engine.recycle_outputs(stage_out);
            }
            std::mem::swap(&mut rows, &mut next_rows);
        }
        scratch.rows = rows;
        scratch.next_rows = next_rows;
        scratch.gathered = gathered;
        result?;
        out.energy_uj = out.stage_counts.iter().zip(&self.stages).map(|(&c, st)| c as f64 * st.energy_uj).sum();
        Ok(())
    }

    /// Run a whole dataset through the ladder (experiment path), chunked
    /// by the spec batch size.
    pub fn infer_dataset(
        &self,
        engine: &mut dyn Backend,
        data: &EvalData,
    ) -> crate::Result<(LadderBatch, BatchOutputs)> {
        let mut agg = LadderBatch {
            pred: Vec::with_capacity(data.n),
            margin: Vec::with_capacity(data.n),
            stage: Vec::with_capacity(data.n),
            stage_counts: vec![0; self.stages.len()],
            energy_uj: 0.0,
            first_pred: Vec::with_capacity(data.n),
            first_margin: Vec::with_capacity(data.n),
            n_classes: 0,
        };
        let mut chunkid = 0u32;
        let mut lo = 0;
        while lo < data.n {
            let hi = (lo + self.spec.batch).min(data.n);
            let out = self.infer_batch(engine, data.rows(lo, hi), hi - lo, chunkid)?;
            agg.pred.extend(out.pred);
            agg.margin.extend(out.margin);
            agg.stage.extend(out.stage);
            for (a, b) in agg.stage_counts.iter_mut().zip(&out.stage_counts) {
                *a += b;
            }
            agg.energy_uj += out.energy_uj;
            agg.first_pred.extend(out.first_pred);
            agg.first_margin.extend(out.first_margin);
            agg.n_classes = out.n_classes;
            lo = hi;
            chunkid += 1;
        }
        let outputs = BatchOutputs {
            scores: Vec::new(),
            pred: agg.pred.clone(),
            margin: agg.margin.clone(),
            batch: data.n,
            n_classes: agg.n_classes,
        };
        Ok((agg, outputs))
    }

    /// Energy savings vs always-full, from served energy (the paper's
    /// eq. 2 on the realised per-stage fractions).
    pub fn realised_savings(&self, batch: &LadderBatch) -> f64 {
        let n = batch.pred.len() as f64;
        if n == 0.0 {
            return 0.0;
        }
        1.0 - batch.energy_uj / (n * self.e_full())
    }

    /// Multi-line per-stage calibration summary (levels, changed-element
    /// counts, thresholds, per-inference energies).
    pub fn calibration_report(&self) -> String {
        let mut s = String::new();
        for (i, st) in self.stages.iter().enumerate() {
            let label = match self.spec.mode {
                Mode::Fp => format!("FP{}", st.variant.level),
                Mode::Sc => format!("L={}", st.variant.level),
            };
            match &st.calibration {
                Some(cal) => s.push_str(&format!(
                    "  stage {i} ({label}): {}, E = {:.4} µJ\n",
                    cal.summary(st.threshold),
                    st.energy_uj
                )),
                None => s.push_str(&format!("  stage {i} ({label}): final, E = {:.4} µJ\n", st.energy_uj)),
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::VariantKind;

    fn dummy_ladder(mode: Mode, n_stages: usize) -> Ladder {
        let spec = LadderSpec {
            dataset: "d".into(),
            mode,
            levels: (0..n_stages).map(|i| 8 + 4 * i).collect(),
            batch: 32,
            threshold: ThresholdPolicy::MMax,
            seed: 0xA41,
        };
        let stages = spec
            .levels
            .iter()
            .map(|&level| LadderStage {
                variant: VariantRef {
                    dataset: "d".into(),
                    kind: VariantKind::Sc,
                    level,
                    batch: 32,
                    file: String::new(),
                },
                threshold: 0.0,
                calibration: None,
                energy_uj: level as f64,
                class_thresholds: Vec::new(),
                base_escalation: 0.0,
            })
            .collect();
        Ladder { spec, stages }
    }

    #[test]
    fn spec_from_config_uses_ladder_levels() {
        let mut cfg = AriConfig::default();
        cfg.reduced_level = 8;
        let spec = LadderSpec::from_config(&cfg);
        assert_eq!(spec.levels, vec![8, 16]);
        cfg.levels = vec![8, 12, 16];
        let spec = LadderSpec::from_config(&cfg);
        assert_eq!(spec.levels, vec![8, 12, 16]);
    }

    #[test]
    fn sc_keys_distinct_per_stage_and_cascade_compatible() {
        let ladder = dummy_ladder(Mode::Sc, 3);
        let seed = ladder.spec.seed;
        let k0 = ladder.key_for(0, 7).unwrap();
        let k1 = ladder.key_for(1, 7).unwrap();
        let k2 = ladder.key_for(2, 7).unwrap();
        // Stage 0/1 match the original cascade's reduced/full keys.
        assert_eq!(k0, [seed, 7]);
        assert_eq!(k1, [seed ^ 0x5A5A_5A5A, 7]);
        assert_ne!(k1, k2);
        assert_ne!(k0, k2);
    }

    #[test]
    fn fp_has_no_keys() {
        let ladder = dummy_ladder(Mode::Fp, 2);
        assert!(ladder.key_for(0, 1).is_none());
        assert!(ladder.key_for(1, 1).is_none());
    }

    #[test]
    fn batch_fractions_and_escalation() {
        let b = LadderBatch {
            pred: vec![0; 4],
            margin: vec![0.0; 4],
            stage: vec![0, 1, 2, 0],
            stage_counts: vec![4, 2, 1],
            energy_uj: 0.0,
            first_pred: vec![0; 4],
            first_margin: vec![0.0; 4],
            n_classes: 10,
        };
        assert_eq!(b.stage_fractions(), vec![1.0, 0.5, 0.25]);
        assert!((b.escalation_fraction() - 0.5).abs() < 1e-12);
    }
}
