//! The ARI coordinator — the paper's system contribution as a serving
//! component.
//!
//! * [`batcher`] — dynamic batching queue (size + deadline policy);
//! * [`cascade`] — the two-tier adaptive-resolution cascade: calibrate a
//!   threshold on a calibration split, then serve every batch reduced-
//!   first and escalate only low-margin samples to the full model
//!   (paper Fig. 7b), with per-inference energy accounting (eq. 1).

pub mod batcher;
pub mod cascade;

pub use batcher::{Batch, Batcher, BatcherPolicy};
pub use cascade::{Cascade, CascadeBatch, CascadeSpec, EscalationPolicy};
