//! The ARI coordinator — the paper's system contribution as a serving
//! component.
//!
//! * [`batcher`] — dynamic batching queue (size + deadline policy);
//! * [`ladder`] — the N-level adaptive-resolution ladder: each non-final
//!   stage is calibrated against the full model on a calibration split,
//!   and a batch flows down the ladder — rows accepted at stage i stop
//!   there, the rest escalate — with per-stage energy accounting
//!   `E = Σ_i f_i · E_i` (the paper's eq. 1 generalised);
//! * [`cascade`] — the paper's two-tier special case, kept as a thin
//!   wrapper over a 2-level ladder (paper Fig. 7b);
//! * [`control`] — the closed-loop threshold controller: per-class
//!   thresholds, load-adaptive tighten/relax with hysteresis, and drift
//!   detection with bounded online recalibration.

pub mod batcher;
pub mod cascade;
pub mod control;
pub mod ladder;

pub use batcher::{Batch, Batcher, BatcherPolicy, FireReason, Pending};
pub use cascade::{Cascade, CascadeBatch, CascadeSpec, EscalationPolicy};
pub use control::{ControlPolicy, Controller};
pub use ladder::{Ladder, LadderBatch, LadderScratch, LadderSpec, LadderStage};
