//! The two-tier ARI cascade — now a thin wrapper over the N-level
//! [`Ladder`] (`levels = [reduced, full]`).
//!
//! Calibration (paper §III-C): run the full and reduced models over the
//! calibration split, collect the reduced-model margins of elements whose
//! predicted class differs, and set `T` by the configured policy
//! (Mmax / M99 / M95 / fixed).
//!
//! Serving (paper Fig. 7b): every batch runs on the reduced model; rows
//! whose margin fails `accepts(margin, T)` are gathered, re-run on the
//! full model, and scattered back.  Energy is accounted per inference
//! with the calibrated [`crate::energy::EnergyModel`] (eq. 1).
//!
//! All inference delegates to the 2-level ladder, which is
//! bit-identical to the original standalone implementation (same
//! calibration seeds, same SC key salts — pinned by `tests/ladder.rs`).

use crate::config::{AriConfig, Mode, ThresholdPolicy};
use crate::coordinator::ladder::{Ladder, LadderBatch, LadderSpec};
use crate::data::{EvalData, VariantRef};
use crate::margin::Calibration;
use crate::runtime::{Backend, BatchOutputs};

/// Static description of a cascade (what to build from the manifest).
#[derive(Clone, Debug)]
pub struct CascadeSpec {
    /// Dataset to serve.
    pub dataset: String,
    /// Resolution family.
    pub mode: Mode,
    /// Level of the reduced (first-pass) model.
    pub reduced_level: usize,
    /// Level of the full (escalation) model.
    pub full_level: usize,
    /// Batch size both variants are compiled at.
    pub batch: usize,
    /// Threshold selection policy.
    pub threshold: ThresholdPolicy,
    /// SC key seed (ignored for FP).
    pub seed: u32,
}

impl CascadeSpec {
    /// Derive a spec from the server configuration.
    pub fn from_config(cfg: &AriConfig) -> Self {
        Self {
            dataset: cfg.dataset.clone(),
            mode: cfg.mode,
            reduced_level: cfg.reduced_level,
            full_level: cfg.full_level,
            batch: cfg.batch_size,
            threshold: cfg.threshold,
            seed: cfg.seed as u32,
        }
    }

    /// The equivalent 2-level ladder spec.
    pub fn to_ladder(&self) -> LadderSpec {
        LadderSpec {
            dataset: self.dataset.clone(),
            mode: self.mode,
            levels: vec![self.reduced_level, self.full_level],
            batch: self.batch,
            threshold: self.threshold,
            seed: self.seed,
        }
    }
}

/// When to run the full model for escalated rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EscalationPolicy {
    /// Re-run escalations immediately after each reduced batch (lowest
    /// latency; possibly padded full-model batches).
    Immediate,
    /// Defer escalations into a dedicated queue flushed when full or at
    /// batch deadline (higher full-model utilisation; more latency).
    /// Implemented by the server loop; the cascade exposes the split.
    Deferred,
}

/// Result of one cascaded batch.
#[derive(Clone, Debug)]
pub struct CascadeBatch {
    /// Final predictions (reduced, overwritten by full where escalated).
    pub pred: Vec<i32>,
    /// Final margins (same overwrite rule).
    pub margin: Vec<f32>,
    /// Which rows were escalated to the full model.
    pub escalated: Vec<bool>,
    /// Modelled energy for the batch (µJ), per eq. (1) accounting.
    pub energy_uj: f64,
    /// Reduced-model outputs (before any overwrite) — kept for analysis.
    pub reduced_pred: Vec<i32>,
    /// Classes per row, as reported by the backend outputs.
    pub n_classes: usize,
}

impl CascadeBatch {
    /// View a 2-level ladder batch as a cascade batch.
    fn from_ladder(b: LadderBatch) -> Self {
        Self {
            escalated: b.stage.iter().map(|&s| s > 0).collect(),
            pred: b.pred,
            margin: b.margin,
            energy_uj: b.energy_uj,
            reduced_pred: b.first_pred,
            n_classes: b.n_classes,
        }
    }
}

/// A calibrated, servable cascade (the 2-level [`Ladder`] special case).
pub struct Cascade {
    /// The spec this cascade was built from.
    pub spec: CascadeSpec,
    /// The reduced (first-pass) variant.
    pub reduced: VariantRef,
    /// The full (escalation) variant.
    pub full: VariantRef,
    /// The calibrated margin threshold T.
    pub threshold: f64,
    /// Calibration statistics T was derived from.
    pub calibration: Calibration,
    /// Energy per inference of the reduced model (µJ).
    pub e_reduced: f64,
    /// Energy per inference of the full model (µJ).
    pub e_full: f64,
    /// The underlying 2-level ladder all inference delegates to (also
    /// what [`crate::server::run_serving`] serves from).
    pub ladder: Ladder,
}

impl Cascade {
    /// Build and calibrate: runs full + reduced over `calib` rows
    /// [0, n_calib) of the eval split.
    pub fn calibrate(
        engine: &mut dyn Backend,
        spec: CascadeSpec,
        data: &EvalData,
        n_calib: usize,
    ) -> crate::Result<Self> {
        let ladder = Ladder::calibrate(engine, spec.to_ladder(), data, n_calib)?;
        let calibration = ladder.stages[0].calibration.clone().expect("non-final stage has a calibration");
        Ok(Self {
            spec,
            reduced: ladder.stages[0].variant.clone(),
            full: ladder.stages[1].variant.clone(),
            threshold: ladder.stages[0].threshold,
            calibration,
            e_reduced: ladder.stages[0].energy_uj,
            e_full: ladder.stages[1].energy_uj,
            ladder,
        })
    }

    /// SC key for a reduced-model chunk (None for FP).
    pub fn key_for(&self, key_seed: u32) -> Option<[u32; 2]> {
        self.ladder.key_for(0, key_seed)
    }

    /// Reduced-model pass only (used by the server's deferred-escalation
    /// policy, which manages its own escalation queue).
    pub fn run_reduced(&self, engine: &mut dyn Backend, x: &[f32], n: usize, key_seed: u32) -> crate::Result<BatchOutputs> {
        self.ladder.run_stage(engine, 0, x, n, key_seed)
    }

    /// Full-model pass only.
    pub fn run_full(&self, engine: &mut dyn Backend, x: &[f32], n: usize, key_seed: u32) -> crate::Result<BatchOutputs> {
        self.ladder.run_stage(engine, 1, x, n, key_seed)
    }

    /// Serve one batch of `n` rows through the cascade.
    /// `key_seed` feeds SC key derivation (ignored for FP).
    pub fn infer_batch(
        &self,
        engine: &mut dyn Backend,
        x: &[f32],
        n: usize,
        key_seed: u32,
    ) -> crate::Result<CascadeBatch> {
        Ok(CascadeBatch::from_ladder(self.ladder.infer_batch(engine, x, n, key_seed)?))
    }

    /// Run a whole dataset through the cascade (experiment path).
    pub fn infer_dataset(&self, engine: &mut dyn Backend, data: &EvalData) -> crate::Result<(CascadeBatch, BatchOutputs)> {
        let (batch, outputs) = self.ladder.infer_dataset(engine, data)?;
        Ok((CascadeBatch::from_ladder(batch), outputs))
    }

    /// Observed escalation fraction of a served result.
    pub fn escalation_fraction(batch: &CascadeBatch) -> f64 {
        if batch.escalated.is_empty() {
            return 0.0;
        }
        batch.escalated.iter().filter(|&&e| e).count() as f64 / batch.escalated.len() as f64
    }

    /// Energy savings vs always-full, from served energy (eq. 2 on the
    /// realised F rather than the calibration estimate).
    pub fn realised_savings(&self, batch: &CascadeBatch) -> f64 {
        let n = batch.escalated.len() as f64;
        if n == 0.0 {
            return 0.0;
        }
        1.0 - batch.energy_uj / (n * self.e_full)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_from_config_roundtrip() {
        let mut cfg = AriConfig::default();
        cfg.dataset = "svhn_syn".into();
        cfg.reduced_level = 12;
        let spec = CascadeSpec::from_config(&cfg);
        assert_eq!(spec.dataset, "svhn_syn");
        assert_eq!(spec.reduced_level, 12);
        assert_eq!(spec.full_level, 16);
    }

    #[test]
    fn spec_to_ladder_is_two_level() {
        let mut cfg = AriConfig::default();
        cfg.reduced_level = 8;
        let ladder = CascadeSpec::from_config(&cfg).to_ladder();
        assert_eq!(ladder.levels, vec![8, 16]);
        assert_eq!(ladder.batch, cfg.batch_size);
    }

    #[test]
    fn escalation_fraction_counts() {
        let b = CascadeBatch {
            pred: vec![0; 4],
            margin: vec![0.0; 4],
            escalated: vec![true, false, true, false],
            energy_uj: 0.0,
            reduced_pred: vec![0; 4],
            n_classes: 10,
        };
        assert!((Cascade::escalation_fraction(&b) - 0.5).abs() < 1e-12);
    }
}
