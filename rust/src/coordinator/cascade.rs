//! The two-tier ARI cascade.
//!
//! Calibration (paper §III-C): run the full and reduced models over the
//! calibration split, collect the reduced-model margins of elements whose
//! predicted class differs, and set `T` by the configured policy
//! (Mmax / M99 / M95 / fixed).
//!
//! Serving (paper Fig. 7b): every batch runs on the reduced model; rows
//! whose margin fails `accepts(margin, T)` are gathered, re-run on the
//! full model, and scattered back.  Energy is accounted per inference
//! with the calibrated [`EnergyModel`] (eq. 1).

use crate::config::{AriConfig, Mode, ThresholdPolicy};
use crate::data::{EvalData, VariantRef};
use crate::energy::EnergyModel;
use crate::margin::{accepts, Calibration};
use crate::runtime::{Backend, BatchOutputs};

/// Static description of a cascade (what to build from the manifest).
#[derive(Clone, Debug)]
pub struct CascadeSpec {
    /// Dataset to serve.
    pub dataset: String,
    /// Resolution family.
    pub mode: Mode,
    /// Level of the reduced (first-pass) model.
    pub reduced_level: usize,
    /// Level of the full (escalation) model.
    pub full_level: usize,
    /// Batch size both variants are compiled at.
    pub batch: usize,
    /// Threshold selection policy.
    pub threshold: ThresholdPolicy,
    /// SC key seed (ignored for FP).
    pub seed: u32,
}

impl CascadeSpec {
    /// Derive a spec from the server configuration.
    pub fn from_config(cfg: &AriConfig) -> Self {
        Self {
            dataset: cfg.dataset.clone(),
            mode: cfg.mode,
            reduced_level: cfg.reduced_level,
            full_level: cfg.full_level,
            batch: cfg.batch_size,
            threshold: cfg.threshold,
            seed: cfg.seed as u32,
        }
    }
}

/// When to run the full model for escalated rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EscalationPolicy {
    /// Re-run escalations immediately after each reduced batch (lowest
    /// latency; possibly padded full-model batches).
    Immediate,
    /// Defer escalations into a dedicated queue flushed when full or at
    /// batch deadline (higher full-model utilisation; more latency).
    /// Implemented by the server loop; the cascade exposes the split.
    Deferred,
}

/// Result of one cascaded batch.
#[derive(Clone, Debug)]
pub struct CascadeBatch {
    /// Final predictions (reduced, overwritten by full where escalated).
    pub pred: Vec<i32>,
    /// Final margins (same overwrite rule).
    pub margin: Vec<f32>,
    /// Which rows were escalated to the full model.
    pub escalated: Vec<bool>,
    /// Modelled energy for the batch (µJ), per eq. (1) accounting.
    pub energy_uj: f64,
    /// Reduced-model outputs (before any overwrite) — kept for analysis.
    pub reduced_pred: Vec<i32>,
    /// Classes per row, as reported by the backend outputs.
    pub n_classes: usize,
}

/// A calibrated, servable cascade.
pub struct Cascade {
    /// The spec this cascade was built from.
    pub spec: CascadeSpec,
    /// The reduced (first-pass) variant.
    pub reduced: VariantRef,
    /// The full (escalation) variant.
    pub full: VariantRef,
    /// The calibrated margin threshold T.
    pub threshold: f64,
    /// Calibration statistics T was derived from.
    pub calibration: Calibration,
    /// Energy per inference of the reduced model (µJ).
    pub e_reduced: f64,
    /// Energy per inference of the full model (µJ).
    pub e_full: f64,
}

impl Cascade {
    /// Build and calibrate: runs full + reduced over `calib` rows
    /// [0, n_calib) of the eval split.
    pub fn calibrate(
        engine: &mut dyn Backend,
        spec: CascadeSpec,
        data: &EvalData,
        n_calib: usize,
    ) -> crate::Result<Self> {
        anyhow::ensure!(n_calib > 0 && n_calib <= data.n, "bad calibration size {n_calib}");
        let kind = spec.mode.kind();
        let reduced = engine.manifest().variant(&spec.dataset, kind, spec.reduced_level, spec.batch)?.clone();
        let full = engine.manifest().variant(&spec.dataset, kind, spec.full_level, spec.batch)?.clone();
        let calib_slice = EvalData {
            x: data.rows(0, n_calib).to_vec(),
            y: data.y[..n_calib].to_vec(),
            n: n_calib,
            input_dim: data.input_dim,
        };
        let full_out = engine.run_dataset(&full, &calib_slice, spec.seed)?;
        let red_out = engine.run_dataset(&reduced, &calib_slice, spec.seed.wrapping_add(1))?;
        let calibration = Calibration::from_pairs(&full_out.pred, &red_out.pred, &red_out.margin);
        let threshold = calibration.threshold(spec.threshold);

        let dims = engine.weights(&spec.dataset)?.dims();
        let energy = EnergyModel::for_dims(&dims);
        let (e_reduced, e_full) = match spec.mode {
            Mode::Fp => (
                energy.fp_energy(crate::quant::FpFormat::fp(spec.reduced_level as u32)),
                energy.fp_energy(crate::quant::FpFormat::fp(spec.full_level as u32)),
            ),
            Mode::Sc => (
                energy.sc_energy(crate::sc::ScConfig::new(spec.reduced_level)),
                energy.sc_energy(crate::sc::ScConfig::new(spec.full_level)),
            ),
        };
        Ok(Self { spec, reduced, full, threshold, calibration, e_reduced, e_full })
    }

    /// SC key for a chunk (None for FP).
    pub fn key_for(&self, key_seed: u32) -> Option<[u32; 2]> {
        match self.spec.mode {
            Mode::Sc => Some([self.spec.seed, key_seed]),
            Mode::Fp => None,
        }
    }

    /// Reduced-model pass only (used by the server's deferred-escalation
    /// policy, which manages its own escalation queue).
    pub fn run_reduced(&self, engine: &mut dyn Backend, x: &[f32], n: usize, key_seed: u32) -> crate::Result<BatchOutputs> {
        Ok(engine.run_padded(&self.reduced, x, n, self.key_for(key_seed))?.0)
    }

    /// Full-model pass only.
    pub fn run_full(&self, engine: &mut dyn Backend, x: &[f32], n: usize, key_seed: u32) -> crate::Result<BatchOutputs> {
        let key = self.key_for(key_seed).map(|[a, b]| [a ^ 0x5A5A_5A5A, b]);
        Ok(engine.run_padded(&self.full, x, n, key)?.0)
    }

    /// Serve one batch of `n` rows through the cascade.
    /// `key_seed` feeds SC key derivation (ignored for FP).
    pub fn infer_batch(
        &self,
        engine: &mut dyn Backend,
        x: &[f32],
        n: usize,
        key_seed: u32,
    ) -> crate::Result<CascadeBatch> {
        let key = self.key_for(key_seed);
        let (red, _) = engine.run_padded(&self.reduced, x, n, key)?;
        let mut pred = red.pred.clone();
        let mut margin = red.margin.clone();
        let mut escalated = vec![false; n];
        let mut esc_rows: Vec<usize> = Vec::new();
        for i in 0..n {
            if !accepts(red.margin[i], self.threshold) {
                escalated[i] = true;
                esc_rows.push(i);
            }
        }
        if !esc_rows.is_empty() {
            let input_dim = x.len() / n;
            // Gather escalated rows (they may exceed one full-model batch).
            for chunk in esc_rows.chunks(self.full.batch) {
                let mut gathered = Vec::with_capacity(chunk.len() * input_dim);
                for &i in chunk {
                    gathered.extend_from_slice(&x[i * input_dim..(i + 1) * input_dim]);
                }
                let fkey = key.map(|[a, b]| [a ^ 0x5A5A_5A5A, b]);
                let (fout, _) = engine.run_padded(&self.full, &gathered, chunk.len(), fkey)?;
                for (j, &i) in chunk.iter().enumerate() {
                    pred[i] = fout.pred[j];
                    margin[i] = fout.margin[j];
                }
            }
        }
        let energy_uj = n as f64 * self.e_reduced + esc_rows.len() as f64 * self.e_full;
        Ok(CascadeBatch { pred, margin, escalated, energy_uj, reduced_pred: red.pred, n_classes: red.n_classes })
    }

    /// Run a whole dataset through the cascade (experiment path).
    pub fn infer_dataset(&self, engine: &mut dyn Backend, data: &EvalData) -> crate::Result<(CascadeBatch, BatchOutputs)> {
        let mut agg = CascadeBatch {
            pred: Vec::with_capacity(data.n),
            margin: Vec::with_capacity(data.n),
            escalated: Vec::with_capacity(data.n),
            energy_uj: 0.0,
            reduced_pred: Vec::with_capacity(data.n),
            n_classes: 0,
        };
        let mut chunkid = 0u32;
        let mut lo = 0;
        while lo < data.n {
            let hi = (lo + self.spec.batch).min(data.n);
            let out = self.infer_batch(engine, data.rows(lo, hi), hi - lo, chunkid)?;
            agg.pred.extend(out.pred);
            agg.margin.extend(out.margin);
            agg.escalated.extend(out.escalated);
            agg.energy_uj += out.energy_uj;
            agg.reduced_pred.extend(out.reduced_pred);
            agg.n_classes = out.n_classes;
            lo = hi;
            chunkid += 1;
        }
        // Class count comes from the backend outputs, not an assumption
        // about the dataset (non-10-class datasets report correctly).
        let outputs = BatchOutputs {
            scores: Vec::new(),
            pred: agg.pred.clone(),
            margin: agg.margin.clone(),
            batch: data.n,
            n_classes: agg.n_classes,
        };
        Ok((agg, outputs))
    }

    /// Observed escalation fraction of a served result.
    pub fn escalation_fraction(batch: &CascadeBatch) -> f64 {
        if batch.escalated.is_empty() {
            return 0.0;
        }
        batch.escalated.iter().filter(|&&e| e).count() as f64 / batch.escalated.len() as f64
    }

    /// Energy savings vs always-full, from served energy (eq. 2 on the
    /// realised F rather than the calibration estimate).
    pub fn realised_savings(&self, batch: &CascadeBatch) -> f64 {
        let n = batch.escalated.len() as f64;
        if n == 0.0 {
            return 0.0;
        }
        1.0 - batch.energy_uj / (n * self.e_full)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_from_config_roundtrip() {
        let mut cfg = AriConfig::default();
        cfg.dataset = "svhn_syn".into();
        cfg.reduced_level = 12;
        let spec = CascadeSpec::from_config(&cfg);
        assert_eq!(spec.dataset, "svhn_syn");
        assert_eq!(spec.reduced_level, 12);
        assert_eq!(spec.full_level, 16);
    }

    #[test]
    fn escalation_fraction_counts() {
        let b = CascadeBatch {
            pred: vec![0; 4],
            margin: vec![0.0; 4],
            escalated: vec![true, false, true, false],
            energy_uj: 0.0,
            reduced_pred: vec![0; 4],
            n_classes: 10,
        };
        assert!((Cascade::escalation_fraction(&b) - 0.5).abs() < 1e-12);
    }
}
