//! `artifacts/manifest.txt` — the discovery file the python exporter
//! writes and everything on the rust side starts from.

use std::path::{Path, PathBuf};

/// Which resolution family a variant belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum VariantKind {
    /// Floating point; `level` is the total bit width (paper's FPk).
    Fp,
    /// Stochastic computing; `level` is the sequence length L.
    Sc,
}

impl VariantKind {
    fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "fp" => Ok(VariantKind::Fp),
            "sc" => Ok(VariantKind::Sc),
            other => anyhow::bail!("unknown variant kind {other:?}"),
        }
    }
}

/// One lowered executable: (dataset, kind, level, batch) -> HLO file.
#[derive(Clone, Debug)]
pub struct VariantRef {
    /// Owning dataset name.
    pub dataset: String,
    /// Resolution family.
    pub kind: VariantKind,
    /// FP bit width or SC sequence length.
    pub level: usize,
    /// Compiled batch size.
    pub batch: usize,
    /// HLO file name inside the dataset directory.
    pub file: String,
}

impl VariantRef {
    /// Stable cache key.
    pub fn key(&self) -> String {
        format!("{}/{:?}{}_b{}", self.dataset, self.kind, self.level, self.batch)
    }
}

/// One exported dataset.
#[derive(Clone, Debug)]
pub struct DatasetEntry {
    /// Dataset name (directory name under the artifacts root).
    pub name: String,
    /// The paper dataset this stands in for.
    pub paper_name: String,
    /// Input feature dimension.
    pub input_dim: usize,
    /// Number of classes.
    pub n_classes: usize,
    /// Eval split size.
    pub n_eval: usize,
    /// Training accuracy recorded at export time.
    pub train_acc: f64,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Artifacts root directory.
    pub root: PathBuf,
    /// Exported datasets.
    pub datasets: Vec<DatasetEntry>,
    /// Lowered executables.
    pub variants: Vec<VariantRef>,
}

impl Manifest {
    /// Load `<root>/manifest.txt`.
    pub fn load(root: &Path) -> crate::Result<Self> {
        let path = root.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e} — run `make artifacts` first"))?;
        // Name the offending file: a malformed manifest must produce an
        // actionable error (pinned by `tests/failure_injection.rs`).
        Self::parse(root, &text).map_err(|e| e.context(format!("manifest {}", path.display())))
    }

    /// Parse manifest text (separated out for tests).
    pub fn parse(root: &Path, text: &str) -> crate::Result<Self> {
        let mut lines = text.lines();
        anyhow::ensure!(lines.next() == Some("ari-manifest v1"), "bad manifest magic");
        let mut datasets = Vec::new();
        let mut variants = Vec::new();
        for (no, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            match parts.next() {
                Some("dataset") => {
                    let name = parts.next().ok_or_else(|| anyhow::anyhow!("line {}: missing name", no + 2))?;
                    let mut e = DatasetEntry {
                        name: name.to_string(),
                        paper_name: String::new(),
                        input_dim: 0,
                        n_classes: 0,
                        n_eval: 0,
                        train_acc: 0.0,
                    };
                    for kv in parts {
                        let (k, v) = kv
                            .split_once('=')
                            .ok_or_else(|| anyhow::anyhow!("line {}: bad kv {kv:?}", no + 2))?;
                        match k {
                            "paper" => e.paper_name = v.replace('_', " "),
                            "input_dim" => e.input_dim = v.parse()?,
                            "n_classes" => e.n_classes = v.parse()?,
                            "n_eval" => e.n_eval = v.parse()?,
                            "train_acc" => e.train_acc = v.parse()?,
                            _ => {} // forward-compatible: ignore unknown keys
                        }
                    }
                    anyhow::ensure!(e.input_dim > 0 && e.n_classes > 0, "line {}: incomplete dataset", no + 2);
                    datasets.push(e);
                }
                Some("variant") => {
                    let dataset = parts.next().ok_or_else(|| anyhow::anyhow!("line {}: missing ds", no + 2))?;
                    let mut kind = None;
                    let mut level = None;
                    let mut batch = None;
                    let mut file = None;
                    for kv in parts {
                        let (k, v) = kv
                            .split_once('=')
                            .ok_or_else(|| anyhow::anyhow!("line {}: bad kv {kv:?}", no + 2))?;
                        match k {
                            "kind" => kind = Some(VariantKind::parse(v)?),
                            "level" => level = Some(v.parse()?),
                            "batch" => batch = Some(v.parse()?),
                            "file" => file = Some(v.to_string()),
                            _ => {}
                        }
                    }
                    variants.push(VariantRef {
                        dataset: dataset.to_string(),
                        kind: kind.ok_or_else(|| anyhow::anyhow!("line {}: no kind", no + 2))?,
                        level: level.ok_or_else(|| anyhow::anyhow!("line {}: no level", no + 2))?,
                        batch: batch.ok_or_else(|| anyhow::anyhow!("line {}: no batch", no + 2))?,
                        file: file.ok_or_else(|| anyhow::anyhow!("line {}: no file", no + 2))?,
                    });
                }
                Some(other) => anyhow::bail!("line {}: unknown record {other:?}", no + 2),
                None => {}
            }
        }
        anyhow::ensure!(!datasets.is_empty(), "manifest has no datasets");
        Ok(Self { root: root.to_path_buf(), datasets, variants })
    }

    /// Find a dataset entry by name.
    pub fn dataset(&self, name: &str) -> crate::Result<&DatasetEntry> {
        self.datasets
            .iter()
            .find(|d| d.name == name)
            .ok_or_else(|| anyhow::anyhow!("dataset {name:?} not in manifest (have {:?})", self.dataset_names()))
    }

    /// All dataset names, manifest order.
    pub fn dataset_names(&self) -> Vec<&str> {
        self.datasets.iter().map(|d| d.name.as_str()).collect()
    }

    /// Directory holding a dataset's artifacts.
    pub fn dataset_dir(&self, name: &str) -> PathBuf {
        self.root.join(name)
    }

    /// Find a specific variant.
    pub fn variant(&self, dataset: &str, kind: VariantKind, level: usize, batch: usize) -> crate::Result<&VariantRef> {
        self.variants
            .iter()
            .find(|v| v.dataset == dataset && v.kind == kind && v.level == level && v.batch == batch)
            .ok_or_else(|| {
                anyhow::anyhow!("variant {dataset}/{kind:?} level={level} batch={batch} not in manifest")
            })
    }

    /// All levels available for (dataset, kind) at some batch size,
    /// descending (full model first).
    pub fn levels(&self, dataset: &str, kind: VariantKind) -> Vec<usize> {
        let mut ls: Vec<usize> = self
            .variants
            .iter()
            .filter(|v| v.dataset == dataset && v.kind == kind)
            .map(|v| v.level)
            .collect();
        ls.sort_unstable();
        ls.dedup();
        ls.reverse();
        ls
    }

    /// Path to a variant's HLO file.
    pub fn hlo_path(&self, v: &VariantRef) -> PathBuf {
        self.root.join(&v.dataset).join(&v.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "ari-manifest v1\n\
dataset fashion_syn paper=Fashion-MNIST input_dim=784 n_classes=10 n_eval=4096 train_acc=0.88\n\
variant fashion_syn kind=fp level=16 batch=32 file=fp16_b32.hlo.txt\n\
variant fashion_syn kind=fp level=10 batch=32 file=fp10_b32.hlo.txt\n\
variant fashion_syn kind=sc level=512 batch=256 file=sc512_b256.hlo.txt\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        assert_eq!(m.datasets.len(), 1);
        assert_eq!(m.datasets[0].paper_name, "Fashion-MNIST");
        assert_eq!(m.variants.len(), 3);
        let v = m.variant("fashion_syn", VariantKind::Fp, 10, 32).unwrap();
        assert_eq!(v.file, "fp10_b32.hlo.txt");
        assert!(m.hlo_path(v).ends_with("fashion_syn/fp10_b32.hlo.txt"));
    }

    #[test]
    fn levels_sorted_descending() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        assert_eq!(m.levels("fashion_syn", VariantKind::Fp), vec![16, 10]);
    }

    #[test]
    fn missing_variant_is_error() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        assert!(m.variant("fashion_syn", VariantKind::Fp, 12, 32).is_err());
        assert!(m.dataset("nope").is_err());
    }

    #[test]
    fn rejects_bad_magic_and_records() {
        assert!(Manifest::parse(Path::new("/t"), "nope\n").is_err());
        assert!(Manifest::parse(Path::new("/t"), "ari-manifest v1\nbogus x\n").is_err());
        assert!(Manifest::parse(Path::new("/t"), "ari-manifest v1\n").is_err()); // no datasets
    }

    #[test]
    fn unknown_keys_ignored() {
        let text = "ari-manifest v1\ndataset d paper=P input_dim=4 n_classes=2 n_eval=1 train_acc=0.5 future=zzz\n";
        let m = Manifest::parse(Path::new("/t"), text).unwrap();
        assert_eq!(m.datasets[0].input_dim, 4);
    }
}
