//! The `.bin`/`.meta` raw-tensor container written by the python
//! exporter's `BinWriter` — little-endian blobs plus a line-based header:
//!
//! ```text
//! ari-meta v1
//! tensor <name> <dtype> <rank> <dim0> ... <dimN-1> <byte_offset> <byte_len>
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Supported element types.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    I32,
    /// 32-bit unsigned integer.
    U32,
}

impl DType {
    fn parse(s: &str) -> crate::Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u32" => DType::U32,
            other => anyhow::bail!("unknown dtype {other:?}"),
        })
    }

    /// Bytes per element.
    pub fn size(&self) -> usize {
        4
    }
}

/// One tensor view into the container.
#[derive(Clone, Debug)]
pub struct Tensor {
    /// Tensor name (header key).
    pub name: String,
    /// Element type.
    pub dtype: DType,
    /// Shape.
    pub dims: Vec<usize>,
    raw: Vec<u8>,
}

impl Tensor {
    /// Product of the dims.
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    /// Decode as f32 (errors on dtype mismatch).
    pub fn as_f32(&self) -> crate::Result<Vec<f32>> {
        anyhow::ensure!(self.dtype == DType::F32, "{} is not f32", self.name);
        Ok(self.raw.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    /// Decode as i32 (errors on dtype mismatch).
    pub fn as_i32(&self) -> crate::Result<Vec<i32>> {
        anyhow::ensure!(self.dtype == DType::I32, "{} is not i32", self.name);
        Ok(self.raw.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }
}

/// A parsed container: all tensors of one `<base>.bin`/`<base>.meta` pair.
#[derive(Clone, Debug)]
pub struct TensorFile {
    /// Path of the pair without extension.
    pub base: PathBuf,
    entries: BTreeMap<String, Tensor>,
}

impl TensorFile {
    /// Open `<base>.bin` + `<base>.meta`.
    pub fn open(base: &Path) -> crate::Result<Self> {
        let meta_path = base.with_extension("meta");
        let bin_path = base.with_extension("bin");
        let meta = std::fs::read_to_string(&meta_path)
            .map_err(|e| anyhow::anyhow!("reading {meta_path:?}: {e}"))?;
        let blob = std::fs::read(&bin_path).map_err(|e| anyhow::anyhow!("reading {bin_path:?}: {e}"))?;
        let mut lines = meta.lines();
        anyhow::ensure!(lines.next() == Some("ari-meta v1"), "bad meta magic in {meta_path:?}");
        let mut entries = BTreeMap::new();
        for (no, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            anyhow::ensure!(parts.len() >= 6 && parts[0] == "tensor", "bad meta line {}: {line:?}", no + 2);
            let name = parts[1].to_string();
            let dtype = DType::parse(parts[2])?;
            let rank: usize = parts[3].parse()?;
            anyhow::ensure!(parts.len() == 6 + rank, "bad field count on line {}", no + 2);
            let dims: Vec<usize> =
                parts[4..4 + rank].iter().map(|p| p.parse()).collect::<Result<_, _>>()?;
            let offset: usize = parts[4 + rank].parse()?;
            let len: usize = parts[5 + rank].parse()?;
            anyhow::ensure!(offset + len <= blob.len(), "tensor {name} overruns blob");
            anyhow::ensure!(
                len == dims.iter().product::<usize>() * dtype.size(),
                "tensor {name}: byte length {len} != shape {dims:?}"
            );
            entries.insert(
                name.clone(),
                Tensor { name, dtype, dims, raw: blob[offset..offset + len].to_vec() },
            );
        }
        Ok(Self { base: base.to_path_buf(), entries })
    }

    /// Look up a tensor by name (error lists what exists).
    pub fn get(&self, name: &str) -> crate::Result<&Tensor> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("tensor {name:?} not in {:?} (have: {:?})", self.base, self.names()))
    }

    /// All tensor names in the container.
    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(|s| s.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn write_pair(dir: &Path, base: &str, meta: &str, bin: &[u8]) -> PathBuf {
        let b = dir.join(base);
        std::fs::File::create(b.with_extension("meta")).unwrap().write_all(meta.as_bytes()).unwrap();
        std::fs::File::create(b.with_extension("bin")).unwrap().write_all(bin).unwrap();
        b
    }

    fn tmp() -> PathBuf {
        let d = std::env::temp_dir().join(format!("ari-tensors-{}-{:?}", std::process::id(), std::thread::current().id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_f32_i32() {
        let dir = tmp();
        let mut bin = Vec::new();
        for v in [1.5f32, -2.5] {
            bin.extend_from_slice(&v.to_le_bytes());
        }
        for v in [7i32, -9] {
            bin.extend_from_slice(&v.to_le_bytes());
        }
        let meta = "ari-meta v1\ntensor a f32 2 1 2 0 8\ntensor b i32 1 2 8 8\n";
        let base = write_pair(&dir, "rt", meta, &bin);
        let tf = TensorFile::open(&base).unwrap();
        assert_eq!(tf.get("a").unwrap().as_f32().unwrap(), vec![1.5, -2.5]);
        assert_eq!(tf.get("b").unwrap().as_i32().unwrap(), vec![7, -9]);
        assert_eq!(tf.get("a").unwrap().dims, vec![1, 2]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = tmp();
        let base = write_pair(&dir, "bad", "nope v0\n", &[]);
        assert!(TensorFile::open(&base).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_overrun() {
        let dir = tmp();
        let base = write_pair(&dir, "ov", "ari-meta v1\ntensor a f32 1 4 0 16\n", &[0u8; 8]);
        assert!(TensorFile::open(&base).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_shape_length_mismatch() {
        let dir = tmp();
        let base = write_pair(&dir, "mm", "ari-meta v1\ntensor a f32 1 3 0 8\n", &[0u8; 8]);
        assert!(TensorFile::open(&base).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_tensor_error_lists_names() {
        let dir = tmp();
        let base = write_pair(&dir, "ms", "ari-meta v1\ntensor a f32 1 1 0 4\n", &[0u8; 4]);
        let tf = TensorFile::open(&base).unwrap();
        let err = format!("{:?}", tf.get("zzz").unwrap_err());
        assert!(err.contains("zzz") && err.contains('a'));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn wrong_dtype_access_rejected() {
        let dir = tmp();
        let base = write_pair(&dir, "dt", "ari-meta v1\ntensor a f32 1 1 0 4\n", &[0u8; 4]);
        let tf = TensorFile::open(&base).unwrap();
        assert!(tf.get("a").unwrap().as_i32().is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
