//! Artifact loading: the `.bin`/`.meta` tensor format, the manifest, and
//! dataset/weight views.
//!
//! The python exporter (`python/compile/aot.py::BinWriter`) writes raw
//! little-endian blobs plus line-based headers; this module is the rust
//! side of that contract (no serde in the vendored crate set).

pub mod manifest;
pub mod tensors;

pub use manifest::{Manifest, VariantKind, VariantRef};
pub use tensors::{Tensor, TensorFile};

use std::path::Path;

/// An evaluation dataset: inputs (n, input_dim) and labels (n,).
#[derive(Clone, Debug)]
pub struct EvalData {
    /// Row-major (n, input_dim) inputs.
    pub x: Vec<f32>,
    /// Labels, `n` long.
    pub y: Vec<i32>,
    /// Number of rows.
    pub n: usize,
    /// Features per row.
    pub input_dim: usize,
}

impl EvalData {
    /// Load `eval.bin`/`eval.meta` from a dataset artifact directory.
    pub fn load(ds_dir: &Path) -> crate::Result<Self> {
        let tf = TensorFile::open(&ds_dir.join("eval"))?;
        let x = tf.get("x")?;
        let y = tf.get("y")?;
        anyhow::ensure!(x.dims.len() == 2, "eval x must be 2-D, got {:?}", x.dims);
        let (n, input_dim) = (x.dims[0], x.dims[1]);
        anyhow::ensure!(y.dims == vec![n], "label count {:?} != {n}", y.dims);
        Ok(Self { x: x.as_f32()?.to_vec(), y: y.as_i32()?.to_vec(), n, input_dim })
    }

    /// One input row.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.input_dim..(i + 1) * self.input_dim]
    }

    /// Rows [lo, hi) as a contiguous slice.
    pub fn rows(&self, lo: usize, hi: usize) -> &[f32] {
        &self.x[lo * self.input_dim..hi * self.input_dim]
    }
}

/// MLP weights in exporter order: (w, b, alpha) per layer.
#[derive(Clone, Debug)]
pub struct Weights {
    /// Layers in forward order.
    pub layers: Vec<LayerWeights>,
}

/// One dense layer's parameters.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    /// Row-major (in_dim, out_dim).
    pub w: Vec<f32>,
    /// Input width.
    pub in_dim: usize,
    /// Output width.
    pub out_dim: usize,
    /// Bias, `out_dim` long.
    pub b: Vec<f32>,
    /// PReLU negative slope (applied between hidden layers).
    pub alpha: f32,
}

impl Weights {
    /// Load `weights.bin`/`weights.meta` from a dataset artifact dir.
    pub fn load(ds_dir: &Path) -> crate::Result<Self> {
        let tf = TensorFile::open(&ds_dir.join("weights"))?;
        let mut layers = Vec::new();
        for i in 0.. {
            let Ok(w) = tf.get(&format!("layer{i}.w")) else { break };
            let b = tf.get(&format!("layer{i}.b"))?;
            let alpha = tf.get(&format!("layer{i}.alpha"))?;
            anyhow::ensure!(w.dims.len() == 2, "layer{i}.w must be 2-D");
            layers.push(LayerWeights {
                in_dim: w.dims[0],
                out_dim: w.dims[1],
                w: w.as_f32()?.to_vec(),
                b: b.as_f32()?.to_vec(),
                alpha: alpha.as_f32()?[0],
            });
        }
        anyhow::ensure!(!layers.is_empty(), "no layers found in {ds_dir:?}");
        // Chain consistency.
        for pair in layers.windows(2) {
            anyhow::ensure!(pair[0].out_dim == pair[1].in_dim, "layer dim chain broken");
        }
        Ok(Self { layers })
    }

    /// Layer widths including the input: e.g. [784, 1024, ..., 10].
    pub fn dims(&self) -> Vec<usize> {
        let mut d = vec![self.layers[0].in_dim];
        d.extend(self.layers.iter().map(|l| l.out_dim));
        d
    }

    /// Flat (name, dims, data) triples in exporter order — the order the
    /// lowered HLO expects its weight parameters.
    pub fn flat(&self) -> Vec<(String, Vec<usize>, &[f32])> {
        let mut out = Vec::new();
        for (i, l) in self.layers.iter().enumerate() {
            out.push((format!("layer{i}.w"), vec![l.in_dim, l.out_dim], l.w.as_slice()));
            out.push((format!("layer{i}.b"), vec![l.out_dim], l.b.as_slice()));
            out.push((format!("layer{i}.alpha"), vec![1], std::slice::from_ref(&l.alpha)));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    /// Write a tiny fake artifact dir and load it back.
    fn fake_dir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("ari-data-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // weights: 2 layers (3 -> 2 -> 2)
        let mut bin: Vec<u8> = Vec::new();
        let mut meta = String::from("ari-meta v1\n");
        let add = |name: &str, dims: &[usize], vals: &[f32], bin: &mut Vec<u8>, meta: &mut String| {
            let off = bin.len();
            for v in vals {
                bin.extend_from_slice(&v.to_le_bytes());
            }
            let dimstr = dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(" ");
            meta.push_str(&format!("tensor {name} f32 {} {dimstr} {off} {}\n", dims.len(), vals.len() * 4));
        };
        add("layer0.w", &[3, 2], &[1., 2., 3., 4., 5., 6.], &mut bin, &mut meta);
        add("layer0.b", &[2], &[0.1, 0.2], &mut bin, &mut meta);
        add("layer0.alpha", &[1], &[0.25], &mut bin, &mut meta);
        add("layer1.w", &[2, 2], &[1., 0., 0., 1.], &mut bin, &mut meta);
        add("layer1.b", &[2], &[0., 0.], &mut bin, &mut meta);
        add("layer1.alpha", &[1], &[0.1], &mut bin, &mut meta);
        std::fs::File::create(dir.join("weights.bin")).unwrap().write_all(&bin).unwrap();
        std::fs::File::create(dir.join("weights.meta")).unwrap().write_all(meta.as_bytes()).unwrap();
        dir
    }

    #[test]
    fn loads_weights() {
        let dir = fake_dir();
        let w = Weights::load(&dir).unwrap();
        assert_eq!(w.layers.len(), 2);
        assert_eq!(w.dims(), vec![3, 2, 2]);
        assert_eq!(w.layers[0].alpha, 0.25);
        assert_eq!(w.flat().len(), 6);
        assert_eq!(w.flat()[0].1, vec![3, 2]);
        std::fs::remove_dir_all(dir).ok();
    }
}
