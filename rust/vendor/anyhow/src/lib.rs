//! Vendored, dependency-free subset of the `anyhow` crate.
//!
//! The build environment for this repository must work fully offline (no
//! crates.io access), so the workspace carries this minimal drop-in
//! replacement as a path dependency.  It implements exactly the surface
//! the `ari` crate uses:
//!
//! * [`Error`] — a boxed, `Display`-able error value,
//! * [`Result`] — `Result<T, Error>` with a defaulted error type,
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the formatting macros,
//! * a blanket `From<E: std::error::Error>` so `?` converts std errors.
//!
//! Swapping this for the real `anyhow` crate is a one-line change in
//! `rust/Cargo.toml` (replace the `path` dependency with a version) and
//! requires no source changes.

use std::fmt;

/// A string-backed error value, API-compatible (for this crate's usage)
/// with `anyhow::Error`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string() }
    }

    /// Wrap the error with additional context, anyhow-style.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`
// (exactly like the real anyhow) — that is what makes the blanket
// conversion below coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let v: i32 = s.parse()?; // From<ParseIntError>
        ensure!(v > 0, "value {v} must be positive");
        if v > 100 {
            bail!("value {v} too large");
        }
        Ok(v)
    }

    #[test]
    fn macros_and_conversions() {
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").unwrap_err().to_string().contains("invalid digit"));
        assert!(parse("-1").unwrap_err().to_string().contains("positive"));
        assert!(parse("500").unwrap_err().to_string().contains("too large"));
        let e = anyhow!("code {}", 42);
        assert_eq!(e.to_string(), "code 42");
        assert_eq!(format!("{e:?}"), "code 42");
        assert_eq!(e.context("outer").to_string(), "outer: code 42");
    }
}
