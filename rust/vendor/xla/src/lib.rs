//! Compile-only stub of the `xla` crate (PJRT C-API bindings).
//!
//! The real PJRT runtime needs `libxla_extension` (a multi-hundred-MB
//! native library) which is not part of the offline build image.  This
//! stub keeps the `--features pjrt` code path *compiling* everywhere: it
//! exposes the exact API surface `ari::runtime::pjrt` consumes, and every
//! entry point fails at **runtime** with a clear error instead of
//! breaking the build.
//!
//! To run the real PJRT path, replace the `path` dependency in
//! `rust/Cargo.toml` with the real `xla` crate (LaurentMazare/xla-rs,
//! pinned against `xla_extension` 0.5.x) — no source changes are needed;
//! the artifact-dependent tests and benches discover `artifacts/` and
//! activate themselves.

use std::fmt;

/// Error produced by every stub entry point.
#[derive(Debug, Clone)]
pub struct Error {
    what: &'static str,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}: the `xla` PJRT stub is linked (offline build); \
             swap rust/vendor/xla for the real xla crate to run this path",
            self.what
        )
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &'static str) -> Result<T, Error> {
    Err(Error { what })
}

/// Stub of a device-resident buffer.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

/// Stub of a compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with borrowed buffers; always fails in the stub.
    pub fn execute_b<I>(&self, _args: &[I]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

impl PjRtBuffer {
    /// Download to a host literal; always fails in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Stub of a host-side literal value.
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Destructure a tuple literal; always fails in the stub.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable("Literal::to_tuple")
    }

    /// Copy out as a typed vector; always fails in the stub.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }
}

/// Stub of a parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse HLO text from a file; always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Stub of an XLA computation.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a module proto (infallible in the real crate too).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Stub of the PJRT client.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU client; always fails in the stub so callers get a
    /// clean error at engine construction instead of deep in serving.
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }

    /// Upload a host buffer; always fails in the stub.
    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }

    /// Compile a computation; always fails in the stub.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}
