"""Reduced-precision (truncated-mantissa) matmul — the FP side of ARI.

The paper's floating-point hardware derives every reduced model from the
FP16 full model by *removing least-significant mantissa bits* (Fig. 2):
FP16 keeps 10 mantissa bits, FP14 keeps 8, ..., FP8 keeps 2, all with the
FP16 5-bit exponent.  This kernel emulates that datapath at the value
level inside f32 compute:

  * inputs are quantised to the target format on load,
  * weights arrive already quantised (done once at export),
  * the MAC accumulation runs in f32 (a stand-in for the wide accumulator
    every MAC array uses),
  * the epilogue re-quantises ``acc + bias`` and applies PReLU, then
    quantises once more — matching a datapath whose registers between
    layers hold reduced-precision values.

TPU adaptation (paper targets a 32 nm ASIC MAC bank, not a GPU): the
64-PE × SRAM banking of the paper maps to a (block_m × K) @ (K × block_n)
VMEM tiling; quantisation is fused into the tile epilogue so the
reduced-precision emulation costs no extra HBM traffic.  Lowered with
``interpret=True`` — the CPU PJRT client cannot execute Mosaic
custom-calls (see DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """An FP16-family format: 1 sign bit, ``e_bits`` exponent, ``m_bits``
    mantissa.  The paper's FPk format is ``QuantSpec(m_bits=k - 6)``
    (k = 1 + 5 + mantissa)."""

    m_bits: int
    e_bits: int = 5

    def __post_init__(self) -> None:
        if not 1 <= self.m_bits <= 23:
            raise ValueError(f"m_bits must be in [1, 23], got {self.m_bits}")
        if not 2 <= self.e_bits <= 8:
            raise ValueError(f"e_bits must be in [2, 8], got {self.e_bits}")

    @property
    def total_bits(self) -> int:
        return 1 + self.e_bits + self.m_bits

    @property
    def max_value(self) -> float:
        """Largest finite magnitude: (2 - 2^-m) * 2^emax."""
        emax = (1 << (self.e_bits - 1)) - 1
        return float((2.0 - 2.0 ** (-self.m_bits)) * 2.0**emax)

    @property
    def min_normal(self) -> float:
        emin = 2 - (1 << (self.e_bits - 1))
        return float(2.0**emin)

    @classmethod
    def fp(cls, total_bits: int) -> "QuantSpec":
        """Paper notation: FP16 = full, FP10 = 6 bits removed, etc."""
        return cls(m_bits=total_bits - 6)


def quantize_fp(x: jax.Array, spec: QuantSpec) -> jax.Array:
    """Round-to-nearest-even truncation of an f32 tensor to ``spec``.

    Bit-exact emulation of dropping mantissa LSBs: the f32 pattern is
    rounded (RNE, carry into the exponent is the correct behaviour) and
    masked; magnitudes are clamped to the format's max and flushed to zero
    below its min normal (subnormals are flushed — the paper's MAC arrays
    do the same; see DESIGN.md).
    """
    x = x.astype(jnp.float32)
    shift = 23 - spec.m_bits
    i = jax.lax.bitcast_convert_type(x, jnp.uint32)
    lsb = (i >> shift) & jnp.uint32(1)
    bias = lsb + jnp.uint32((1 << (shift - 1)) - 1)
    i = (i + bias) & jnp.uint32(0xFFFFFFFF ^ ((1 << shift) - 1))
    q = jax.lax.bitcast_convert_type(i, jnp.float32)
    # Range handling for the narrow exponent.
    q = jnp.clip(q, -spec.max_value, spec.max_value)
    q = jnp.where(jnp.abs(q) < spec.min_normal, 0.0, q)
    # Preserve exact zeros / signs and pass NaN through untouched.
    q = jnp.where(jnp.isnan(x), x, q)
    return q


def _quant_layer_kernel(x_ref, w_ref, b_ref, alpha_ref, o_ref, *, spec: QuantSpec, activate: bool):
    """One (block_m, K) x (K, block_n) tile of the reduced-precision layer.

    CONTRACT: ``w`` must arrive already quantised to ``spec``.  Weight
    quantisation is idempotent and batch-independent, so it is hoisted out
    of the per-call kernel entirely: the rust runtime quantises each
    dataset's weights once per precision level on the host
    (`runtime::Engine::load_dataset` + `quant::FpFormat`, bit-identical to
    `quantize_fp`) and uploads per-level device buffers.  §Perf: this
    removes ~1.6-3.9 M elementwise quantise ops from every execute.
    """
    xq = quantize_fp(x_ref[...], spec)
    acc = jnp.dot(xq, w_ref[...], preferred_element_type=jnp.float32)
    pre = quantize_fp(acc + quantize_fp(b_ref[...], spec), spec)
    if activate:
        alpha = alpha_ref[0]
        pre = jnp.where(pre >= 0.0, pre, alpha * pre)
        pre = quantize_fp(pre, spec)
    o_ref[...] = pre


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target (tile shape must tile
    the array exactly; batch/feature dims here are powers of two or 10)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("spec", "activate"))
def quant_matmul(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    alpha: jax.Array,
    *,
    spec: QuantSpec,
    activate: bool = True,
) -> jax.Array:
    """Reduced-precision MLP layer: ``prelu(quant(quant(x) @ wq + bq))``.

    Args:
      x: (batch, in_dim) activations, f32.
      w: (in_dim, out_dim) weights (pre-quantised at export).
      b: (out_dim,) bias.
      alpha: scalar (1,) PReLU slope; ignored when ``activate=False``.
      spec: target reduced format.
      activate: apply PReLU (hidden layers) or not (output layer).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm = _pick_block(m, 128)
    bn = _pick_block(n, 256)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_quant_layer_kernel, spec=spec, activate=activate),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(x, w, b, alpha)
