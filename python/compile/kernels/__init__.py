"""L1 Pallas kernels for ARI.

Every kernel here is the build-time author path of the three-layer stack:
it lowers (with ``interpret=True``, so plain HLO comes out) into the L2 jax
model, which ``compile.aot`` serialises to HLO text loaded by the rust
runtime.  Nothing in this package is imported at serving time.
"""

from .quant_matmul import quant_matmul, quantize_fp, QuantSpec  # noqa: F401
from .sc_matmul import sc_matmul, sc_sigma, SCSpec  # noqa: F401
