"""Pure reference oracles for the L1 kernels.

Three tiers:

  * ``ref_quant_layer`` / ``ref_quantize_fp`` — straight jnp re-statement of
    the reduced-precision layer, no pallas.  The pallas kernel must match
    these bit-for-bit (``tests/test_quant_kernel.py``).
  * ``ref_sc_layer`` — straight jnp re-statement of the SC noise model.
  * ``sc_exact_*`` — a numpy *bitstream-exact* stochastic-computing
    simulator (LFSR → SNG → bipolar XNOR multiply → APC accumulate).  This
    is the ground truth the noise model is calibrated against, and the
    python twin of ``rust/src/sc/`` (cross-checked through golden vectors
    in ``tests/test_sc_exact.py`` and ``rust/src/sc/golden.rs``).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .quant_matmul import QuantSpec, quantize_fp
from .sc_matmul import SCSpec, sc_sigma, snap_to_grid

# ---------------------------------------------------------------------------
# FP quantisation reference
# ---------------------------------------------------------------------------


def ref_quantize_fp(x: np.ndarray, spec: QuantSpec) -> np.ndarray:
    """Numpy mirror of ``quantize_fp`` (round-to-nearest-even mantissa
    truncation, clamp to format range, flush subnormals)."""
    x = np.asarray(x, dtype=np.float32)
    shift = 23 - spec.m_bits
    i = x.view(np.uint32).copy()
    lsb = (i >> shift) & np.uint32(1)
    bias = lsb + np.uint32((1 << (shift - 1)) - 1)
    i = (i + bias) & np.uint32(0xFFFFFFFF ^ ((1 << shift) - 1))
    q = i.view(np.float32)
    q = np.clip(q, -spec.max_value, spec.max_value)
    q = np.where(np.abs(q) < spec.min_normal, np.float32(0.0), q)
    q = np.where(np.isnan(x), x, q)
    return q.astype(np.float32)


def ref_quant_layer(
    x: np.ndarray,
    w: np.ndarray,
    b: np.ndarray,
    alpha: float,
    spec: QuantSpec,
    activate: bool = True,
) -> np.ndarray:
    """Reference reduced-precision layer (f32 accumulator, quantised
    operands and epilogue) — mirrors ``quant_matmul``."""
    xq = ref_quantize_fp(x, spec)
    wq = ref_quantize_fp(w, spec)
    acc = xq.astype(np.float32) @ wq.astype(np.float32)
    pre = ref_quantize_fp(acc + ref_quantize_fp(b, spec), spec)
    if activate:
        pre = np.where(pre >= 0.0, pre, np.float32(alpha) * pre)
        pre = ref_quantize_fp(pre, spec)
    return pre


# ---------------------------------------------------------------------------
# SC noise-model reference (jnp, no pallas)
# ---------------------------------------------------------------------------


def ref_sc_layer(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    alpha: float,
    eps: jnp.ndarray,
    spec: SCSpec,
    activate: bool = True,
) -> jnp.ndarray:
    """Reference SC noise-model layer — mirrors ``sc_matmul`` (including
    the per-tile max|x|*max|w| scale, assuming a single tile)."""
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    pre = acc + b
    scale = jnp.max(jnp.abs(x)) * jnp.max(jnp.abs(w))
    fan_in = x.shape[-1]
    noisy = pre + sc_sigma(fan_in, spec, scale) * eps
    noisy = snap_to_grid(noisy, spec, scale)
    if activate:
        noisy = jnp.where(noisy >= 0.0, noisy, alpha * noisy)
    return noisy


# ---------------------------------------------------------------------------
# Exact bitstream SC simulator (numpy) — ground truth for calibration
# ---------------------------------------------------------------------------

# Maximal-length taps for Fibonacci LFSRs (XOR form), indexed by width.
_LFSR_TAPS = {
    8: (8, 6, 5, 4),
    10: (10, 7),
    12: (12, 11, 10, 4),
    16: (16, 15, 13, 4),
}


def lfsr_sequence(width: int, seed: int, length: int) -> np.ndarray:
    """``length`` successive states of a maximal Fibonacci LFSR of
    ``width`` bits (states in [1, 2^width - 1]; seed 0 is remapped to 1).

    This is the python twin of ``rust/src/sc/lfsr.rs`` — the golden test
    vectors in tests/golden_lfsr.txt are produced here and re-checked by
    the rust side.
    """
    taps = _LFSR_TAPS[width]
    mask = (1 << width) - 1
    state = seed & mask or 1
    out = np.empty(length, dtype=np.uint32)
    for t in range(length):
        out[t] = state
        fb = 0
        for tap in taps:
            fb ^= state >> (tap - 1)
        fb &= 1
        state = ((state << 1) | fb) & mask
    return out


def sng_bipolar(values: np.ndarray, rng_states: np.ndarray, width: int) -> np.ndarray:
    """Stochastic number generator: compare each value (bipolar, in
    [-1, 1]) against the LFSR state sequence, producing a bit matrix of
    shape ``values.shape + (L,)`` with P(bit=1) = (v + 1) / 2."""
    v = np.clip(np.asarray(values, dtype=np.float64), -1.0, 1.0)
    p = (v + 1.0) / 2.0
    denom = float(1 << width)
    thresholds = np.floor(p * denom)  # bit = 1  iff  state < thresholds
    return (rng_states[None, :] < thresholds[..., None]).astype(np.uint8)


def sc_exact_dot(
    x: np.ndarray,
    w: np.ndarray,
    spec: SCSpec,
    seed: int = 1,
    width: int = 16,
) -> np.ndarray:
    """Bitstream-exact bipolar SC dot product.

    x: (fan_in,) values in [-1, 1];  w: (fan_in, n_out) values in [-1, 1].
    Each operand stream gets an independently-seeded LFSR.  Products are
    XNOR streams; an APC (exact popcount) accumulates over fan-in and
    time.  Returns the (n_out,) estimate of ``x @ w``.
    """
    fan_in = x.shape[0]
    n_out = w.shape[1]
    L = spec.seq_len
    # Independent LFSRs per input stream and per weight stream.
    x_bits = np.empty((fan_in, L), dtype=np.uint8)
    for i in range(fan_in):
        states = lfsr_sequence(width, seed * 2654435761 + i + 1, L)
        x_bits[i] = sng_bipolar(x[i : i + 1], states, width)[0]
    est = np.empty(n_out, dtype=np.float64)
    for j in range(n_out):
        acc = 0
        for i in range(fan_in):
            states = lfsr_sequence(width, (seed + 7919) * 40503 + i * n_out + j + 1, L)
            w_bits = sng_bipolar(w[i : i + 1, j], states, width)[0]
            prod = np.logical_not(np.logical_xor(x_bits[i], w_bits))  # XNOR
            acc += int(prod.sum())  # APC: exact popcount
        # acc counts 1s over fan_in*L product bits; bipolar decode per
        # product is 2p-1, summed over fan_in streams.
        est[j] = 2.0 * acc / L - fan_in
    return est


def sc_exact_layer(
    x: np.ndarray,
    w: np.ndarray,
    b: np.ndarray,
    alpha: float,
    spec: SCSpec,
    seed: int = 1,
    activate: bool = True,
) -> np.ndarray:
    """Bitstream-exact SC layer on normalised (bipolar-range) values:
    SC dot + (exact) bias + PReLU.  Bias and activation are done on the
    counter readout, as in the paper's LFSM design."""
    est = sc_exact_dot(x, w, spec, seed=seed)
    pre = est + b
    if activate:
        pre = np.where(pre >= 0.0, pre, alpha * pre)
    return pre
