"""Synthetic stand-ins for SVHN / CIFAR-10 / Fashion-MNIST.

The paper's datasets are unavailable in this offline sandbox (DESIGN.md §2).
ARI's behaviour depends only on the *score-margin distribution* of a trained
classifier, so each stand-in keeps the original's input dimensionality and
class count and tunes *difficulty* so the trained full-precision MLP lands
in a qualitatively similar accuracy band (Fashion-MNIST easiest, SVHN
middle, CIFAR-10 hardest) — which is what shapes the margin tails ARI keys
on.

Generator: a 10-class Gaussian mixture on a low-dimensional latent manifold
(class prototypes + within-class factors), projected to pixel space through
a fixed random linear "rendering" map, plus pixel noise and a per-sample
contrast jitter.  Everything is seeded and reproducible; the rust side
never regenerates data — it reads the exported binaries.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """A synthetic dataset family, shaped like its paper counterpart."""

    name: str          # artifact directory name
    paper_name: str    # the dataset it stands in for
    input_dim: int
    n_classes: int
    latent_dim: int    # manifold dimensionality (higher = harder)
    class_sep: float   # prototype separation (lower = harder)
    noise: float       # pixel-space noise std (higher = harder)
    cov_dissim: float  # how class-specific the covariances are (lower = harder)
    seed: int


# Difficulty tuning: Fashion-MNIST-like easiest, SVHN-like middle,
# CIFAR-10-like hardest, mirroring the relative accuracy ordering of the
# paper's MLPs (~87 / ~78 / ~46 %).
SPECS = {
    "fashion_syn": DatasetSpec(
        name="fashion_syn", paper_name="Fashion-MNIST", input_dim=784,
        n_classes=10, latent_dim=20, class_sep=1.60, noise=1.0, cov_dissim=0.35, seed=101,
    ),
    "svhn_syn": DatasetSpec(
        name="svhn_syn", paper_name="SVHN", input_dim=3072,
        n_classes=10, latent_dim=28, class_sep=1.05, noise=1.3, cov_dissim=0.25, seed=202,
    ),
    "cifar10_syn": DatasetSpec(
        name="cifar10_syn", paper_name="CIFAR-10", input_dim=3072,
        n_classes=10, latent_dim=48, class_sep=0.62, noise=1.7, cov_dissim=0.12, seed=303,
    ),
}


def generate(spec: DatasetSpec, n: int, split_seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Draw ``n`` samples from the dataset family.

    Returns (x, y): x is (n, input_dim) f32 standardised to roughly unit
    scale; y is (n,) int32 labels.  ``split_seed`` decorrelates splits
    while the class geometry (prototypes, rendering map) stays fixed by
    ``spec.seed``.
    """
    geom = np.random.RandomState(spec.seed)
    protos = geom.randn(spec.n_classes, spec.latent_dim) * spec.class_sep
    # Within-class factor loadings: mostly *shared* covariance structure
    # (otherwise the MLP classifies classes by covariance alone and every
    # dataset saturates), with a class-specific component scaled by
    # ``cov_dissim`` that makes margins class-dependent and heavy-tailed,
    # like natural images.
    shared = geom.randn(spec.latent_dim, spec.latent_dim) * 0.9
    deltas = geom.randn(spec.n_classes, spec.latent_dim, spec.latent_dim) * 0.9
    w_shared = np.sqrt(1.0 - spec.cov_dissim**2)
    factors = w_shared * shared[None, :, :] + spec.cov_dissim * deltas
    render = geom.randn(spec.latent_dim, spec.input_dim) / np.sqrt(spec.latent_dim)

    rs = np.random.RandomState(split_seed)
    y = rs.randint(0, spec.n_classes, size=n).astype(np.int32)
    z = protos[y] + np.einsum("nk,nkl->nl", rs.randn(n, spec.latent_dim), factors[y])
    x = z @ render
    # Per-sample contrast jitter (multiplicative) + pixel noise: makes the
    # score distribution heteroscedastic, again like natural images.
    contrast = np.exp(rs.randn(n, 1) * 0.15)
    x = x * contrast + rs.randn(n, spec.input_dim) * spec.noise
    x = (x - x.mean(axis=1, keepdims=True)) / (x.std(axis=1, keepdims=True) + 1e-6)
    return x.astype(np.float32), y


def splits(spec: DatasetSpec, n_train: int, n_eval: int):
    """Standard (train, eval) splits.  The eval split doubles as the
    paper's 'dataset' used both for threshold calibration and reporting —
    exactly the paper's protocol (§III-C uses the dataset itself)."""
    x_tr, y_tr = generate(spec, n_train, split_seed=spec.seed * 7 + 1)
    x_ev, y_ev = generate(spec, n_eval, split_seed=spec.seed * 7 + 2)
    return (x_tr, y_tr), (x_ev, y_ev)
