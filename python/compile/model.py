"""L2 — the paper's MLP in JAX, in all resolution variants.

Topology (paper §II-C / §IV): input – 1024 – 512 – 256 – 256 – 10 with
PReLU activations.  Trained once in f32 (``train.py``); at export the
*full* model is the FP16-semantics forward (paper: "pre-trained as the
full precision model ... with format FP16") and every reduced model is a
mantissa-truncated or shorter-bitstream variant of the same weights —
no retraining, exactly the paper's setup.

Each forward returns ``(scores, pred, margin)`` with the margin
``M = S1st − S2nd`` computed *inside the graph*, so the rust hot path gets
it for free (one device round trip, no host-side top-k).

All heavy math goes through the L1 pallas kernels
(``kernels.quant_matmul`` / ``kernels.sc_matmul``).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import QuantSpec, SCSpec, quant_matmul, sc_matmul

HIDDEN = (1024, 512, 256, 256)
N_CLASSES = 10

FULL_FP = QuantSpec.fp(16)     # the paper's full floating-point model
FULL_SC_LEN = 4096             # the paper's full stochastic-computing model


class LayerParams(NamedTuple):
    w: jax.Array      # (in_dim, out_dim)
    b: jax.Array      # (out_dim,)
    alpha: jax.Array  # (1,) PReLU slope


def layer_dims(input_dim: int) -> list[tuple[int, int]]:
    dims = (input_dim, *HIDDEN, N_CLASSES)
    return list(zip(dims[:-1], dims[1:]))


def init_params(key: jax.Array, input_dim: int) -> list[LayerParams]:
    """He-initialised parameters for the 5-layer MLP."""
    params = []
    for d_in, d_out in layer_dims(input_dim):
        key, wk = jax.random.split(key)
        w = jax.random.normal(wk, (d_in, d_out), jnp.float32) * jnp.sqrt(2.0 / d_in)
        params.append(
            LayerParams(w=w, b=jnp.zeros((d_out,), jnp.float32), alpha=jnp.full((1,), 0.25, jnp.float32))
        )
    return params


def params_to_flat(params: list[LayerParams]) -> list[tuple[str, jax.Array]]:
    """Stable (name, tensor) listing used by the AOT exporter and the rust
    weight loader — order must match ``rust/src/data/weights.rs``."""
    out = []
    for i, p in enumerate(params):
        out.append((f"layer{i}.w", p.w))
        out.append((f"layer{i}.b", p.b))
        out.append((f"layer{i}.alpha", p.alpha))
    return out


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _top2_margin(scores: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(pred, margin) via masked reductions.  ``jax.lax.top_k`` lowers to a
    TopK HLO attribute the xla crate's 0.5.1 parser rejects, so the top-2
    is computed with two plain max-reduces instead (cheap for 10 classes,
    and parses everywhere)."""
    s1 = jnp.max(scores, axis=-1)
    pred = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    classes = jnp.arange(scores.shape[-1], dtype=jnp.int32)
    masked = jnp.where(classes[None, :] == pred[:, None], -jnp.inf, scores)
    s2 = jnp.max(masked, axis=-1)
    return pred, s1 - s2


def _normalize(logits: jax.Array) -> jax.Array:
    """Scores = L2-normalised logits.

    The paper's classifier scores are the raw (bounded) outputs of the
    last layer — counter readouts in the SC design, datapath values in
    the FP design — NOT softmax probabilities.  That distinction matters
    for ARI: a resolution-induced class flip happens exactly when the two
    top *raw* scores cross, so changed elements have small raw margins,
    while softmax saturation would hand even borderline flips a margin
    near 1 and destroy the threshold structure (margins of Figs. 8/10/11).
    Per-sample L2 normalisation bounds the scores like the paper's
    hardware range does, without distorting the top-2 gap ordering.
    """
    norm = jnp.sqrt(jnp.sum(logits * logits, axis=-1, keepdims=True) + 1e-12)
    return logits / norm


def _outputs(logits: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(scores, pred, margin): normalised scores in [-1, 1], the arg-max
    class, and the top-1 − top-2 score margin (paper §III-B)."""
    scores = _normalize(logits)
    pred, margin = _top2_margin(scores)
    return scores, pred, margin


def forward_train(params: list[LayerParams], x: jax.Array) -> jax.Array:
    """Plain f32 forward (no pallas, differentiable) used only by
    ``train.py``.  Returns logits."""
    h = x
    for p in params[:-1]:
        pre = h @ p.w + p.b
        h = jnp.where(pre >= 0.0, pre, p.alpha[0] * pre)
    last = params[-1]
    return h @ last.w + last.b


def forward_fp(params: list[LayerParams], x: jax.Array, spec: QuantSpec):
    """Reduced-precision (or FP16 full) forward through the L1 pallas
    kernel.  ``spec=FULL_FP`` is the paper's full model."""
    h = x
    for p in params[:-1]:
        h = quant_matmul(h, p.w, p.b, p.alpha, spec=spec, activate=True)
    last = params[-1]
    logits = quant_matmul(h, last.w, last.b, last.alpha, spec=spec, activate=False)
    return _outputs(logits)


def forward_sc(params: list[LayerParams], x: jax.Array, key: jax.Array, spec: SCSpec):
    """Stochastic-computing forward (noise model) through the L1 pallas
    kernel.  ``key`` is an explicit threefry key input so the lowered HLO
    is a pure, deterministic function of (x, key)."""
    h = x
    keys = jax.random.split(key, len(params))
    for i, p in enumerate(params[:-1]):
        eps = jax.random.normal(keys[i], (x.shape[0], p.w.shape[1]), jnp.float32)
        h = sc_matmul(h, p.w, p.b, p.alpha, eps, spec=spec, activate=True)
    last = params[-1]
    eps = jax.random.normal(keys[-1], (x.shape[0], last.w.shape[1]), jnp.float32)
    logits = sc_matmul(h, last.w, last.b, last.alpha, eps, spec=spec, activate=False)
    scores = _normalize(logits)
    # Counter-grid readout: scores themselves come off L-bit counters
    # (bipolar grid of step 2/L on the normalised range).
    scores = jnp.round(scores * (spec.seq_len / 2)) / (spec.seq_len / 2)
    pred, margin = _top2_margin(scores)
    return scores, pred, margin


# Entry points the AOT exporter lowers (weights are *parameters* of the
# HLO, passed by the rust runtime as device buffers created once).


def fp_entry(spec: QuantSpec):
    def fn(x, *flat_w):
        params = unflatten(flat_w)
        return forward_fp(params, x, spec)

    return fn


def sc_entry(spec: SCSpec):
    def fn(x, key, *flat_w):
        params = unflatten(flat_w)
        return forward_sc(params, x, key, spec)

    return fn


def unflatten(flat_w) -> list[LayerParams]:
    assert len(flat_w) % 3 == 0, len(flat_w)
    return [LayerParams(*flat_w[i : i + 3]) for i in range(0, len(flat_w), 3)]
