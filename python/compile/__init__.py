"""ARI build-time compile package (L1 kernels + L2 model + AOT export).

This package runs exactly once, from ``make artifacts``.  The rust serving
binary never imports python; it loads the HLO text + raw binaries this
package writes into ``artifacts/``.
"""
