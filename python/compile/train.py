"""Build-time training of the full-precision MLPs (one per dataset).

Paper protocol (§IV): the MLP is pre-trained as the full-precision model;
every reduced model reuses the same weights.  Training is plain f32 Adam +
cross-entropy on the synthetic datasets; the FP16 "full model" semantics
are applied at inference time by the quantising forward.

Runs once from ``compile.aot``; never at serving time.  Sizes default to
sandbox-friendly values (single CPU core) and are overridable via CLI for
a faithful 20-epoch run.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets, model


def cross_entropy(params, x, y):
    logits = model.forward_train(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


@functools.partial(jax.jit, static_argnames=("lr",))
def adam_step(params, opt_state, x, y, step, lr=1e-3):
    """One Adam step (hand-rolled — optax is not in the sandbox)."""
    b1, b2, eps = 0.9, 0.999, 1e-8
    loss, grads = jax.value_and_grad(cross_entropy)(params, x, y)
    m, v = opt_state
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    t = step + 1
    mhat = jax.tree.map(lambda a: a / (1 - b1**t), m)
    vhat = jax.tree.map(lambda a: a / (1 - b2**t), v)
    params = jax.tree.map(lambda p, a, b: p - lr * a / (jnp.sqrt(b) + eps), params, mhat, vhat)
    return params, (m, v), loss


def train(
    spec: datasets.DatasetSpec,
    n_train: int = 4096,
    n_eval: int = 4096,
    epochs: int = 12,
    batch: int = 256,
    lr: float = 1e-3,
    log=print,
):
    """Train one MLP; returns (params, (x_eval, y_eval), history).

    ``history`` is a list of (epoch, loss, eval_acc) rows recorded for
    EXPERIMENTS.md §E2E (the loss-curve requirement).
    """
    (x_tr, y_tr), (x_ev, y_ev) = datasets.splits(spec, n_train, n_eval)
    params = model.init_params(jax.random.PRNGKey(spec.seed), spec.input_dim)
    zeros = jax.tree.map(jnp.zeros_like, params)
    opt_state = (zeros, jax.tree.map(jnp.zeros_like, zeros))

    eval_fn = jax.jit(lambda p, x: jnp.argmax(model.forward_train(p, x), axis=-1))
    history = []
    step = 0
    n_batches = n_train // batch
    rs = np.random.RandomState(spec.seed + 9)
    t0 = time.time()
    for epoch in range(epochs):
        perm = rs.permutation(n_train)
        losses = []
        for b in range(n_batches):
            idx = perm[b * batch : (b + 1) * batch]
            params, opt_state, loss = adam_step(params, opt_state, x_tr[idx], y_tr[idx], step, lr=lr)
            losses.append(float(loss))
            step += 1
        preds = np.asarray(eval_fn(params, x_ev))
        acc = float((preds == y_ev).mean())
        history.append((epoch, float(np.mean(losses)), acc))
        log(f"[train:{spec.name}] epoch {epoch:2d} loss {np.mean(losses):.4f} eval_acc {acc:.4f} ({time.time()-t0:.0f}s)")
    return params, (x_ev, y_ev), history
