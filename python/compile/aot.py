"""AOT exporter — the single build-time entry point (``make artifacts``).

For every synthetic dataset this:

  1. trains the full-precision MLP (``train.py``),
  2. exports weights, the evaluation split and the training log as raw
     little-endian binaries with line-based ``.meta`` headers (the rust
     loader in ``rust/src/data/`` parses exactly this format — no serde in
     the sandbox's vendored crate set),
  3. lowers every resolution variant of the L2 model to **HLO text**
     (NOT ``.serialize()`` — jax >= 0.5 emits 64-bit instruction ids that
     the xla crate's xla_extension 0.5.1 rejects; the text parser
     reassigns ids and round-trips cleanly, see
     /opt/xla-example/README.md) into ``artifacts/<ds>/<variant>_b<B>.hlo.txt``,
  4. writes a ``manifest.txt`` the rust side uses to discover everything.

Variants (paper §IV): floating point FP16 (full), FP14, FP12, FP10, FP9,
FP8; stochastic computing L = 4096 (full), 2048, 1024, 512, 256, 128, 64.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import datasets, model, train
from .kernels import QuantSpec, SCSpec

FP_BITS = [16, 14, 12, 10, 9, 8]          # FP16 is the full model
SC_LENS = [4096, 2048, 1024, 512, 256, 128, 64]  # 4096 is the full model
BATCH_SIZES = [32, 256]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Binary export (the .bin/.meta format shared with rust/src/data/)
# ---------------------------------------------------------------------------

_DTYPE_NAMES = {np.dtype(np.float32): "f32", np.dtype(np.int32): "i32", np.dtype(np.uint32): "u32"}


class BinWriter:
    """Accumulates named tensors into one .bin blob + .meta header.

    .meta format (one record per line, space separated):
        ari-meta v1
        tensor <name> <dtype> <rank> <dim0> ... <dimN-1> <byte_offset> <byte_len>
    """

    def __init__(self) -> None:
        self.blobs: list[bytes] = []
        self.lines: list[str] = ["ari-meta v1"]
        self.offset = 0

    def add(self, name: str, arr: np.ndarray) -> None:
        arr = np.ascontiguousarray(arr)
        dt = _DTYPE_NAMES[arr.dtype]
        raw = arr.tobytes()
        dims = " ".join(str(d) for d in arr.shape)
        self.lines.append(
            f"tensor {name} {dt} {arr.ndim} {dims} {self.offset} {len(raw)}".replace("  ", " ")
        )
        self.blobs.append(raw)
        self.offset += len(raw)

    def write(self, path_base: str) -> None:
        with open(path_base + ".bin", "wb") as f:
            for b in self.blobs:
                f.write(b)
        with open(path_base + ".meta", "w") as f:
            f.write("\n".join(self.lines) + "\n")


# ---------------------------------------------------------------------------
# Per-dataset export
# ---------------------------------------------------------------------------


def export_dataset(spec: datasets.DatasetSpec, out_dir: str, args) -> dict:
    ds_dir = os.path.join(out_dir, spec.name)
    os.makedirs(ds_dir, exist_ok=True)
    t0 = time.time()
    params, (x_ev, y_ev), history = train.train(
        spec, n_train=args.train_n, n_eval=args.eval_n, epochs=args.epochs, batch=args.train_batch
    )

    # Weights.
    w = BinWriter()
    for name, tensor in model.params_to_flat(params):
        w.add(name, np.asarray(tensor))
    w.write(os.path.join(ds_dir, "weights"))

    # Eval split (the paper's calibration-and-reporting dataset).
    d = BinWriter()
    d.add("x", x_ev)
    d.add("y", y_ev)
    d.write(os.path.join(ds_dir, "eval"))

    # Golden outputs: jax-side (scores, pred, margin) on the first 32 eval
    # samples for three representative variants.  The rust integration
    # tests (rust/tests/runtime_parity.rs) re-run the same HLO through the
    # PJRT runtime and assert bit-parity — the cross-language correctness
    # signal of the whole AOT bridge.
    g = BinWriter()
    xg = x_ev[:32]
    flat0 = [np.asarray(t) for _, t in model.params_to_flat(params)]
    from .kernels.ref import ref_quantize_fp

    for bits in (16, min(args.fp_bits)):
        # Kernel contract: weights arrive pre-quantised (w tensors only —
        # index 0 of each (w, b, alpha) triple); mirrors the rust runtime.
        spec_b = QuantSpec.fp(bits)
        flat_q = [ref_quantize_fp(t, spec_b) if i % 3 == 0 else t for i, t in enumerate(flat0)]
        s, p, m = jax.jit(model.fp_entry(QuantSpec.fp(bits)))(xg, *flat_q)
        g.add(f"fp{bits}.scores", np.asarray(s))
        g.add(f"fp{bits}.pred", np.asarray(p))
        g.add(f"fp{bits}.margin", np.asarray(m))
    key = jnp.array([1, 42], dtype=jnp.uint32)
    sc_l = args.sc_lens[len(args.sc_lens) // 2]
    s, p, m = jax.jit(model.sc_entry(SCSpec(sc_l)))(xg, key, *flat0)
    g.add(f"sc{sc_l}.scores", np.asarray(s))
    g.add(f"sc{sc_l}.pred", np.asarray(p))
    g.add(f"sc{sc_l}.margin", np.asarray(m))
    g.write(os.path.join(ds_dir, "golden"))
    with open(os.path.join(ds_dir, "golden.cfg"), "w") as f:
        f.write(f"fp_bits 16 {min(args.fp_bits)}\nsc_len {sc_l}\nkey 1 42\nbatch 32\n")

    # Training log (loss curve for EXPERIMENTS.md §E2E).
    with open(os.path.join(ds_dir, "train_log.txt"), "w") as f:
        f.write("epoch loss eval_acc\n")
        for epoch, loss, acc in history:
            f.write(f"{epoch} {loss:.6f} {acc:.6f}\n")

    # HLO variants.
    flat = [np.asarray(t) for _, t in model.params_to_flat(params)]
    w_shapes = [jax.ShapeDtypeStruct(t.shape, t.dtype) for t in flat]
    variants = []
    for bsz in args.batch_sizes:
        x_shape = jax.ShapeDtypeStruct((bsz, spec.input_dim), jnp.float32)
        for bits in args.fp_bits:
            name = f"fp{bits}_b{bsz}"
            fn = model.fp_entry(QuantSpec.fp(bits))
            lowered = jax.jit(fn).lower(x_shape, *w_shapes)
            _write_hlo(ds_dir, name, to_hlo_text(lowered))
            variants.append(("fp", bits, bsz, name))
            print(f"  lowered {spec.name}/{name}", flush=True)
        key_shape = jax.ShapeDtypeStruct((2,), jnp.uint32)
        for L in args.sc_lens:
            name = f"sc{L}_b{bsz}"
            fn = model.sc_entry(SCSpec(L))
            lowered = jax.jit(fn).lower(x_shape, key_shape, *w_shapes)
            _write_hlo(ds_dir, name, to_hlo_text(lowered))
            variants.append(("sc", L, bsz, name))
            print(f"  lowered {spec.name}/{name}", flush=True)

    final_acc = history[-1][2]
    print(f"[aot] {spec.name}: acc={final_acc:.4f} variants={len(variants)} ({time.time()-t0:.0f}s)")
    return {"spec": spec, "variants": variants, "acc": final_acc, "n_eval": len(y_ev)}


def _write_hlo(ds_dir: str, name: str, text: str) -> None:
    with open(os.path.join(ds_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(text)


def write_manifest(out_dir: str, results: list[dict], args) -> None:
    """manifest.txt — discovery file for the rust side (line-based)."""
    lines = ["ari-manifest v1"]
    for r in results:
        spec: datasets.DatasetSpec = r["spec"]
        lines.append(
            f"dataset {spec.name} paper={spec.paper_name.replace(' ', '_')} "
            f"input_dim={spec.input_dim} n_classes={spec.n_classes} "
            f"n_eval={r['n_eval']} train_acc={r['acc']:.6f}"
        )
        for kind, level, bsz, name in r["variants"]:
            lines.append(f"variant {spec.name} kind={kind} level={level} batch={bsz} file={name}.hlo.txt")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description="ARI AOT exporter")
    p.add_argument("--out", default="../artifacts")
    p.add_argument("--datasets", nargs="*", default=list(datasets.SPECS))
    p.add_argument("--epochs", type=int, default=12)
    p.add_argument("--train-n", type=int, default=4096)
    p.add_argument("--eval-n", type=int, default=4096)
    p.add_argument("--train-batch", type=int, default=256)
    p.add_argument("--batch-sizes", type=int, nargs="*", default=BATCH_SIZES)
    p.add_argument("--fp-bits", type=int, nargs="*", default=FP_BITS)
    p.add_argument("--sc-lens", type=int, nargs="*", default=SC_LENS)
    p.add_argument("--quick", action="store_true", help="tiny run for CI smoke tests")
    args = p.parse_args(argv)
    if args.quick:
        args.epochs, args.train_n, args.eval_n = 2, 512, 512
        args.batch_sizes, args.fp_bits, args.sc_lens = [32], [16, 10], [4096, 512]
        args.datasets = ["fashion_syn"]

    os.makedirs(args.out, exist_ok=True)
    results = []
    for name in args.datasets:
        results.append(export_dataset(datasets.SPECS[name], args.out, args))
    write_manifest(args.out, results, args)
    print(f"[aot] wrote manifest for {len(results)} datasets to {args.out}")


if __name__ == "__main__":
    main()
