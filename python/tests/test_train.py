"""Training loop: quick convergence and determinism checks (tiny sizes)."""

import dataclasses

import numpy as np

from compile import datasets, train


def tiny_spec():
    return dataclasses.replace(datasets.SPECS["fashion_syn"], input_dim=64, latent_dim=8)


def test_loss_decreases_and_history_recorded():
    params, (x_ev, y_ev), history = train.train(
        tiny_spec(), n_train=512, n_eval=256, epochs=3, batch=128, log=lambda *a: None
    )
    losses = [h[1] for h in history]
    assert len(history) == 3
    assert losses[-1] < losses[0] * 0.9, losses
    accs = [h[2] for h in history]
    assert accs[-1] > 0.2  # far above 10% chance even on a tiny budget


def test_training_deterministic():
    _, _, h1 = train.train(tiny_spec(), n_train=256, n_eval=128, epochs=2, batch=128, log=lambda *a: None)
    _, _, h2 = train.train(tiny_spec(), n_train=256, n_eval=128, epochs=2, batch=128, log=lambda *a: None)
    np.testing.assert_allclose([x[1] for x in h1], [x[1] for x in h2], rtol=1e-5)


def test_eval_split_differs_from_train():
    (x_tr, _), (x_ev, _) = datasets.splits(tiny_spec(), 128, 128)
    assert x_tr.shape == x_ev.shape
    assert not np.allclose(x_tr, x_ev)
