"""L1 correctness: the pallas SC noise-model kernel vs the pure reference,
plus the statistical properties the noise model must satisfy."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import SCSpec, sc_matmul, sc_sigma
from compile.kernels.ref import ref_sc_layer

DIMS = st.sampled_from([1, 4, 8, 10, 16, 32, 64, 128])
LENS = st.sampled_from([64, 128, 256, 512, 1024, 4096])


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, L=LENS, seed=st.integers(0, 2**16), activate=st.booleans())
def test_kernel_matches_reference(m, k, n, L, seed, activate):
    """Single-tile shapes: kernel output == jnp reference (same eps)."""
    rs = np.random.RandomState(seed)
    x = rs.randn(m, k).astype(np.float32)
    w = (rs.randn(k, n) * 0.1).astype(np.float32)
    b = (rs.randn(n) * 0.1).astype(np.float32)
    eps = rs.randn(m, n).astype(np.float32)
    alpha = np.float32(0.25)
    spec = SCSpec(L)
    out = np.asarray(
        sc_matmul(jnp.array(x), jnp.array(w), jnp.array(b), jnp.full((1,), alpha), jnp.array(eps), spec=spec, activate=activate)
    )
    ref = np.asarray(ref_sc_layer(jnp.array(x), jnp.array(w), jnp.array(b), alpha, jnp.array(eps), spec, activate=activate))
    np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-6)


def test_determinism_same_eps():
    rs = np.random.RandomState(1)
    x = rs.randn(8, 32).astype(np.float32)
    w = rs.randn(32, 16).astype(np.float32) * 0.1
    b = np.zeros(16, np.float32)
    eps = rs.randn(8, 16).astype(np.float32)
    a = jnp.full((1,), 0.25)
    spec = SCSpec(256)
    o1 = np.asarray(sc_matmul(jnp.array(x), jnp.array(w), jnp.array(b), a, jnp.array(eps), spec=spec))
    o2 = np.asarray(sc_matmul(jnp.array(x), jnp.array(w), jnp.array(b), a, jnp.array(eps), spec=spec))
    np.testing.assert_array_equal(o1, o2)


def test_noise_shrinks_with_length():
    """std(SC output - exact output) must scale ~ 1/sqrt(L)."""
    rs = np.random.RandomState(2)
    x = rs.randn(64, 128).astype(np.float32)
    w = (rs.randn(128, 32) * 0.1).astype(np.float32)
    b = np.zeros(32, np.float32)
    a = jnp.full((1,), 0.25)
    eps = rs.randn(64, 32).astype(np.float32)
    exact = np.asarray(jnp.maximum(jnp.array(x) @ jnp.array(w), 0.25 * (jnp.array(x) @ jnp.array(w))))
    stds = []
    for L in (64, 256, 1024, 4096):
        out = np.asarray(sc_matmul(jnp.array(x), jnp.array(w), jnp.array(b), a, jnp.array(eps), spec=SCSpec(L)))
        stds.append(float(np.std(out - exact)))
    # each 4x length increase should shrink std by ~2x (allow slack for the
    # grid-snapping floor at small L)
    assert stds[0] > stds[1] > stds[2] > stds[3]
    assert stds[0] / stds[2] > 2.0


def test_sigma_model_formula():
    spec = SCSpec(1024)
    s = float(sc_sigma(256, spec, 1.0))
    assert s == pytest.approx(0.72 / 48.0 * np.sqrt(256 / 1024), rel=1e-6)


def test_infinite_length_limit():
    """As L -> huge, the SC layer approaches the exact f32 layer."""
    rs = np.random.RandomState(3)
    x = rs.randn(16, 64).astype(np.float32)
    w = (rs.randn(64, 16) * 0.1).astype(np.float32)
    b = (rs.randn(16) * 0.1).astype(np.float32)
    a = jnp.full((1,), 0.25)
    eps = rs.randn(16, 16).astype(np.float32)
    out = np.asarray(sc_matmul(jnp.array(x), jnp.array(w), jnp.array(b), a, jnp.array(eps), spec=SCSpec(2**22)))
    pre = x @ w + b
    exact = np.where(pre >= 0, pre, 0.25 * pre)
    np.testing.assert_allclose(out, exact, rtol=1e-2, atol=1e-2)


def test_invalid_spec_rejected():
    with pytest.raises(ValueError):
        SCSpec(100)  # not a power of two
    with pytest.raises(ValueError):
        SCSpec(1)
