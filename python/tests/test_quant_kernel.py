"""L1 correctness: the pallas quant kernel vs the pure reference.

The pallas kernel must match ``ref_quant_layer`` bit-for-bit, and the
quantiser itself must satisfy the format's algebraic properties.  Shapes
and formats are swept with hypothesis per the repro brief.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import QuantSpec, quant_matmul, quantize_fp
from compile.kernels.ref import ref_quant_layer, ref_quantize_fp

DIMS = st.sampled_from([1, 2, 4, 8, 10, 16, 32, 64, 128, 256])
MBITS = st.sampled_from([2, 3, 4, 6, 8, 10])


def _rand(rs, *shape):
    return (rs.randn(*shape) * rs.uniform(0.05, 2.0)).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, mbits=MBITS, seed=st.integers(0, 2**16), activate=st.booleans())
def test_kernel_matches_reference(m, k, n, mbits, seed, activate):
    rs = np.random.RandomState(seed)
    x, w, b = _rand(rs, m, k), _rand(rs, k, n) * 0.1, _rand(rs, n) * 0.1
    alpha = np.float32(rs.uniform(0.0, 0.5))
    spec = QuantSpec(m_bits=mbits)
    # Kernel contract: w arrives pre-quantised (the rust runtime quantises
    # per level on the host); the reference quantises internally, which is
    # idempotent, so feeding it raw w is equivalent.
    wq = ref_quantize_fp(w, spec)
    out = np.asarray(
        quant_matmul(jnp.array(x), jnp.array(wq), jnp.array(b), jnp.full((1,), alpha), spec=spec, activate=activate)
    )
    ref = ref_quant_layer(x, w, b, alpha, spec, activate=activate)
    # XLA's dot and numpy's @ may accumulate in different orders, so a
    # pre-quantisation result can land 1 ULP across a rounding boundary and
    # move one quantisation step.  Allow exactly that much and no more.
    np.testing.assert_allclose(out, ref, rtol=2.0**-spec.m_bits, atol=1e-6)


@settings(max_examples=50, deadline=None)
@given(mbits=MBITS, ebits=st.sampled_from([4, 5, 6]), seed=st.integers(0, 2**16))
def test_quantize_idempotent(mbits, ebits, seed):
    """q(q(x)) == q(x): a quantised value is a fixed point of the format."""
    rs = np.random.RandomState(seed)
    x = (rs.randn(256) * np.logspace(-3, 3, 256)).astype(np.float32)
    spec = QuantSpec(m_bits=mbits, e_bits=ebits)
    q1 = np.asarray(quantize_fp(jnp.array(x), spec))
    q2 = np.asarray(quantize_fp(jnp.array(q1), spec))
    np.testing.assert_array_equal(q1, q2)


@settings(max_examples=50, deadline=None)
@given(mbits=MBITS, seed=st.integers(0, 2**16))
def test_quantize_matches_numpy_ref(mbits, seed):
    rs = np.random.RandomState(seed)
    x = (rs.randn(512) * np.logspace(-4, 4, 512)).astype(np.float32)
    spec = QuantSpec(m_bits=mbits)
    np.testing.assert_array_equal(np.asarray(quantize_fp(jnp.array(x), spec)), ref_quantize_fp(x, spec))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_error_shrinks_with_precision(seed):
    """More mantissa bits -> monotonically no-worse worst-case error."""
    rs = np.random.RandomState(seed)
    x = rs.randn(1024).astype(np.float32)
    errs = []
    for mbits in (2, 4, 6, 8, 10):
        q = ref_quantize_fp(x, QuantSpec(m_bits=mbits))
        errs.append(np.max(np.abs(q - x)))
    assert all(a >= b - 1e-9 for a, b in zip(errs, errs[1:])), errs


def test_relative_error_bound():
    """|q(x) - x| <= 2^-(m+1) * |x| for normal-range values (RNE)."""
    rs = np.random.RandomState(0)
    x = (rs.randn(4096) * np.logspace(-2, 2, 4096)).astype(np.float32)
    for mbits in (2, 4, 6, 8, 10):
        spec = QuantSpec(m_bits=mbits)
        q = ref_quantize_fp(x, spec)
        mask = (np.abs(x) > spec.min_normal * 2) & (np.abs(x) < spec.max_value / 2)
        rel = np.abs(q[mask] - x[mask]) / np.abs(x[mask])
        assert rel.max() <= 2.0 ** -(mbits + 1) + 1e-7, (mbits, rel.max())


def test_special_values():
    spec = QuantSpec.fp(10)
    x = np.array([0.0, -0.0, 1.0, -1.0, 1e9, -1e9, 1e-9, np.nan], dtype=np.float32)
    q = ref_quantize_fp(x, spec)
    assert q[0] == 0.0 and q[1] == 0.0
    assert q[2] == 1.0 and q[3] == -1.0
    assert q[4] == spec.max_value and q[5] == -spec.max_value  # clamp
    assert q[6] == 0.0  # flush below min normal
    assert np.isnan(q[7])


def test_fp16_spec_constants():
    spec = QuantSpec.fp(16)
    assert spec.m_bits == 10 and spec.e_bits == 5
    assert spec.max_value == pytest.approx(65504.0)
    assert spec.min_normal == pytest.approx(2.0**-14)


def test_fp16_halfway_rounds_to_even():
    """1 + 2^-11 is exactly halfway between FP16 neighbours 1 and 1+2^-10;
    RNE must pick the even one (1.0)."""
    spec = QuantSpec.fp(16)
    x = np.array([1.0 + 2.0**-11], dtype=np.float32)
    assert ref_quantize_fp(x, spec)[0] == 1.0
    # just above halfway rounds up
    x = np.array([1.0 + 2.0**-11 + 2.0**-20], dtype=np.float32)
    assert ref_quantize_fp(x, spec)[0] == np.float32(1.0 + 2.0**-10)


def test_invalid_specs_rejected():
    with pytest.raises(ValueError):
        QuantSpec(m_bits=0)
    with pytest.raises(ValueError):
        QuantSpec(m_bits=24)
    with pytest.raises(ValueError):
        QuantSpec(m_bits=4, e_bits=1)
