"""L2 model tests: shapes, margin semantics, variant consistency."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import QuantSpec, SCSpec

INPUT_DIM = 64  # small stand-in; topology logic is dim-independent


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0), INPUT_DIM)


def test_layer_dims_topology():
    dims = model.layer_dims(784)
    assert dims == [(784, 1024), (1024, 512), (512, 256), (256, 256), (256, 10)]


def test_init_shapes(params):
    assert len(params) == 5
    assert params[0].w.shape == (INPUT_DIM, 1024)
    assert params[-1].w.shape == (256, 10)
    for p in params:
        assert p.alpha.shape == (1,)


def test_flat_roundtrip(params):
    flat = [t for _, t in model.params_to_flat(params)]
    back = model.unflatten(flat)
    for a, b in zip(params, back):
        np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))
        np.testing.assert_array_equal(np.asarray(a.b), np.asarray(b.b))


def test_fp_forward_shapes_and_ranges(params):
    x = jax.random.normal(jax.random.PRNGKey(1), (8, INPUT_DIM))
    scores, pred, margin = model.forward_fp(params, x, QuantSpec.fp(16))
    assert scores.shape == (8, 10) and pred.shape == (8,) and margin.shape == (8,)
    s = np.asarray(scores)
    np.testing.assert_allclose((s * s).sum(axis=-1), 1.0, rtol=1e-4)
    m = np.asarray(margin)
    assert (m >= 0).all() and (m <= np.sqrt(2.0) + 1e-6).all()


def test_margin_is_top1_minus_top2(params):
    x = jax.random.normal(jax.random.PRNGKey(2), (16, INPUT_DIM))
    scores, pred, margin = model.forward_fp(params, x, QuantSpec.fp(16))
    s = np.asarray(scores)
    srt = np.sort(s, axis=-1)
    np.testing.assert_allclose(np.asarray(margin), srt[:, -1] - srt[:, -2], rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(pred), s.argmax(axis=-1))


def test_fp16_close_to_train_forward(params):
    """The FP16 'full model' must track the f32 training forward closely —
    it is the paper's reference point."""
    x = jax.random.normal(jax.random.PRNGKey(3), (8, INPUT_DIM))
    logits = np.asarray(model.forward_train(params, x))
    s_ref = logits / np.linalg.norm(logits, axis=-1, keepdims=True)
    s_fp, _, _ = model.forward_fp(params, x, QuantSpec.fp(16))
    np.testing.assert_allclose(np.asarray(s_fp), s_ref, atol=5e-3)


@settings(max_examples=8, deadline=None)
@given(bits=st.sampled_from([8, 10, 12, 14]), seed=st.integers(0, 1000))
def test_quant_deviation_grows_as_bits_drop(params, bits, seed):
    """Score deviation from FP16 should not explode, and coarser formats
    deviate at least as much as finer ones on average."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (8, INPUT_DIM))
    s16, _, _ = model.forward_fp(params, x, QuantSpec.fp(16))
    sq, _, _ = model.forward_fp(params, x, QuantSpec.fp(bits))
    dev = float(np.mean(np.abs(np.asarray(sq) - np.asarray(s16))))
    assert np.isfinite(dev)
    if bits >= 12:
        assert dev < 0.15


def test_sc_forward_deterministic_in_key(params):
    x = jax.random.normal(jax.random.PRNGKey(4), (8, INPUT_DIM))
    key = jnp.array([1, 42], dtype=jnp.uint32)
    s1, p1, m1 = model.forward_sc(params, x, key, SCSpec(512))
    s2, p2, m2 = model.forward_sc(params, x, key, SCSpec(512))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    s3, _, _ = model.forward_sc(params, x, jnp.array([9, 9], dtype=jnp.uint32), SCSpec(512))
    assert not np.array_equal(np.asarray(s1), np.asarray(s3))


def test_sc_scores_on_counter_grid(params):
    x = jax.random.normal(jax.random.PRNGKey(5), (4, INPUT_DIM))
    L = 256
    scores, _, _ = model.forward_sc(params, x, jnp.array([1, 2], dtype=jnp.uint32), SCSpec(L))
    s = np.asarray(scores) * (L / 2)  # bipolar grid: step 2/L
    np.testing.assert_allclose(s, np.round(s), atol=1e-4)


def test_sc_approaches_fp_at_long_lengths(params):
    x = jax.random.normal(jax.random.PRNGKey(6), (8, INPUT_DIM))
    key = jnp.array([3, 4], dtype=jnp.uint32)
    s_long, p_long, _ = model.forward_sc(params, x, key, SCSpec(2**20))
    logits = model.forward_train(params, x)
    p_ref = np.asarray(jnp.argmax(logits, axis=-1))
    agree = (np.asarray(p_long) == p_ref).mean()
    assert agree >= 0.75  # long streams should mostly agree with exact
