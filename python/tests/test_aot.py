"""AOT exporter: the .bin/.meta format contract with rust, and a quick
end-to-end export (tiny config) checking every artifact exists and the
manifest is parseable."""

import os

import numpy as np
import pytest

from compile.aot import BinWriter, main as aot_main


def test_binwriter_layout(tmp_path):
    w = BinWriter()
    a = np.arange(6, dtype=np.float32).reshape(2, 3)
    b = np.array([1, 2, 3], dtype=np.int32)
    w.add("a", a)
    w.add("b", b)
    w.write(str(tmp_path / "t"))
    blob = (tmp_path / "t.bin").read_bytes()
    assert len(blob) == 6 * 4 + 3 * 4
    np.testing.assert_array_equal(np.frombuffer(blob[:24], np.float32).reshape(2, 3), a)
    np.testing.assert_array_equal(np.frombuffer(blob[24:], np.int32), b)
    meta = (tmp_path / "t.meta").read_text().splitlines()
    assert meta[0] == "ari-meta v1"
    assert meta[1].split() == ["tensor", "a", "f32", "2", "2", "3", "0", "24"]
    assert meta[2].split() == ["tensor", "b", "i32", "1", "3", "24", "12"]


def test_binwriter_noncontiguous(tmp_path):
    w = BinWriter()
    arr = np.arange(12, dtype=np.float32).reshape(3, 4).T  # non-contiguous
    w.add("t", arr)
    w.write(str(tmp_path / "nc"))
    blob = (tmp_path / "nc.bin").read_bytes()
    np.testing.assert_array_equal(np.frombuffer(blob, np.float32).reshape(4, 3), arr)


@pytest.mark.slow
def test_quick_export_end_to_end(tmp_path):
    """Full tiny export: train 2 epochs on 512 samples, lower 2 fp + 2 sc
    variants, and verify every file the rust loader expects."""
    out = str(tmp_path / "artifacts")
    aot_main(["--out", out, "--quick"])
    ds = os.path.join(out, "fashion_syn")
    for f in [
        "weights.bin", "weights.meta", "eval.bin", "eval.meta",
        "golden.bin", "golden.meta", "golden.cfg", "train_log.txt",
        "fp16_b32.hlo.txt", "fp10_b32.hlo.txt", "sc4096_b32.hlo.txt", "sc512_b32.hlo.txt",
    ]:
        assert os.path.exists(os.path.join(ds, f)), f
    manifest = open(os.path.join(out, "manifest.txt")).read().splitlines()
    assert manifest[0] == "ari-manifest v1"
    ds_lines = [l for l in manifest if l.startswith("dataset ")]
    var_lines = [l for l in manifest if l.startswith("variant ")]
    assert len(ds_lines) == 1 and len(var_lines) == 4
    # HLO text must carry the ENTRY computation marker the rust parser needs
    hlo = open(os.path.join(ds, "fp16_b32.hlo.txt")).read()
    assert "ENTRY" in hlo
