"""Synthetic dataset generators: determinism, shape, standardisation and
the difficulty ordering that mirrors the paper's datasets."""

import numpy as np

from compile import datasets


def test_specs_shapes():
    assert datasets.SPECS["fashion_syn"].input_dim == 784
    assert datasets.SPECS["svhn_syn"].input_dim == 3072
    assert datasets.SPECS["cifar10_syn"].input_dim == 3072
    for s in datasets.SPECS.values():
        assert s.n_classes == 10


def test_generate_deterministic():
    spec = datasets.SPECS["fashion_syn"]
    x1, y1 = datasets.generate(spec, 64, split_seed=5)
    x2, y2 = datasets.generate(spec, 64, split_seed=5)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)


def test_splits_disjoint_statistics():
    spec = datasets.SPECS["fashion_syn"]
    (x_tr, _), (x_ev, _) = datasets.splits(spec, 128, 128)
    assert not np.array_equal(x_tr, x_ev)


def test_standardised():
    spec = datasets.SPECS["svhn_syn"]
    x, _ = datasets.generate(spec, 32, split_seed=1)
    np.testing.assert_allclose(x.mean(axis=1), 0.0, atol=1e-4)
    np.testing.assert_allclose(x.std(axis=1), 1.0, atol=1e-2)


def test_labels_roughly_balanced():
    spec = datasets.SPECS["cifar10_syn"]
    _, y = datasets.generate(spec, 2000, split_seed=2)
    counts = np.bincount(y, minlength=10)
    assert counts.min() > 100  # no empty class


def _fisher_separation(spec, n=600):
    """Between-class / within-class scatter of a class-mean classifier —
    a cheap proxy for dataset difficulty."""
    x, y = datasets.generate(spec, n, split_seed=11)
    means = np.stack([x[y == c].mean(axis=0) for c in range(10)])
    within = np.mean([x[y == c].var(axis=0).mean() for c in range(10)])
    between = means.var(axis=0).mean()
    return between / within


def test_difficulty_ordering():
    """fashion_syn must be the easiest and cifar10_syn the hardest, like
    their paper counterparts (87% / 78% / 46% full-model accuracy)."""
    f = _fisher_separation(datasets.SPECS["fashion_syn"])
    s = _fisher_separation(datasets.SPECS["svhn_syn"])
    c = _fisher_separation(datasets.SPECS["cifar10_syn"])
    assert f > s > c, (f, s, c)
