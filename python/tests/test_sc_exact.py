"""The exact bitstream SC simulator: LFSR properties, SNG statistics, and
dot-product convergence.  Also pins golden LFSR vectors shared with the
rust twin (rust/src/sc/lfsr.rs — same taps, same golden numbers)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.quant_matmul import QuantSpec  # noqa: F401  (import sanity)
from compile.kernels.sc_matmul import SCSpec
from compile.kernels.ref import lfsr_sequence, sng_bipolar, sc_exact_dot, sc_exact_layer


def test_lfsr_maximal_period_8bit():
    seq = lfsr_sequence(8, seed=1, length=255)
    assert len(set(seq.tolist())) == 255  # maximal: every nonzero state once
    assert 0 not in seq


def test_lfsr_maximal_period_10bit():
    seq = lfsr_sequence(10, seed=7, length=1023)
    assert len(set(seq.tolist())) == 1023


def test_lfsr_seed_zero_remapped():
    seq = lfsr_sequence(8, seed=0, length=4)
    assert seq[0] == 1


def test_lfsr_deterministic():
    a = lfsr_sequence(16, seed=1234, length=64)
    b = lfsr_sequence(16, seed=1234, length=64)
    np.testing.assert_array_equal(a, b)


def test_lfsr_golden_vectors():
    """Golden vectors pinned on both sides of the language boundary.
    rust/src/sc/lfsr.rs has the same numbers in its unit tests; if either
    implementation drifts, one of the two test suites fails."""
    assert lfsr_sequence(8, seed=1, length=8).tolist() == [1, 2, 4, 8, 17, 35, 71, 142]
    assert lfsr_sequence(10, seed=1, length=8).tolist() == [1, 2, 4, 8, 16, 32, 64, 129]
    assert lfsr_sequence(16, seed=0xACE1, length=4).tolist() == [44257, 22979, 45958, 26380]


@settings(max_examples=20, deadline=None)
@given(v=st.floats(-1.0, 1.0), width=st.sampled_from([10, 12, 16]), seed=st.integers(1, 2**16))
def test_sng_mean_tracks_value(v, width, seed):
    """A length-(2^w - 1) stream decodes to the encoded value within the
    LFSR's quantisation resolution."""
    L = (1 << width) - 1
    states = lfsr_sequence(width, seed, L)
    bits = sng_bipolar(np.array([v]), states, width)[0]
    decoded = 2.0 * bits.mean() - 1.0
    # full-period count is floor(p * 2^w) - 1 over 2^w - 1 bits: decode
    # bias up to ~2 steps from the floor and ~2p/2^w from the missing
    # zero state — bound at 4.5 quantisation steps
    assert abs(decoded - v) <= 4.5 / (1 << width) + 1e-9


def test_exact_dot_golden_parity_with_rust():
    """Same golden numbers pinned in rust/src/sc/layer.rs
    (golden_parity_with_python) — the cross-language contract."""
    x = np.array([0.5, -0.25, 0.75, -0.875])
    w = np.array([[0.5, -0.5], [0.25, 0.125], [-0.75, 0.375], [0.0625, -0.9375]])
    np.testing.assert_array_equal(sc_exact_dot(x, w, SCSpec(256), seed=3), [-0.3359375, 0.578125])
    np.testing.assert_array_equal(sc_exact_dot(x, w, SCSpec(1024), seed=11), [-0.361328125, 0.744140625])


def test_exact_dot_converges():
    """Bitstream dot error vs true dot shrinks with L ~ 1/sqrt(L)."""
    rs = np.random.RandomState(5)
    fan_in = 32
    x = rs.uniform(-1, 1, fan_in)
    w = rs.uniform(-1, 1, (fan_in, 4))
    true = x @ w
    errs = []
    for L in (256, 4096):
        est = sc_exact_dot(x, w, SCSpec(L), seed=9)
        errs.append(np.abs(est - true).mean())
    assert errs[1] < errs[0]
    assert errs[1] < 0.5  # absolute sanity on the long-stream error


def test_exact_dot_error_scale_matches_model():
    """Empirical MAC std across seeds should be within [0.5, 2] x the
    c*sqrt(fan_in/L) model used by the pallas kernel — this is the
    calibration contract from DESIGN.md §2."""
    rs = np.random.RandomState(6)
    fan_in, L = 24, 512
    x = rs.uniform(-0.8, 0.8, fan_in)
    w = rs.uniform(-0.8, 0.8, (fan_in, 3))
    true = x @ w
    errs = []
    for seed in range(12):
        est = sc_exact_dot(x, w, SCSpec(L), seed=seed * 131 + 7)
        errs.extend((est - true).tolist())
    emp_std = float(np.std(errs))
    model_std = 0.72 * np.sqrt(fan_in / L)
    assert 0.5 * model_std <= emp_std <= 2.0 * model_std, (emp_std, model_std)


def test_exact_layer_activation():
    rs = np.random.RandomState(8)
    x = rs.uniform(-1, 1, 16)
    w = rs.uniform(-1, 1, (16, 4))
    b = np.array([0.1, -0.1, 0.0, 0.05])
    out = sc_exact_layer(x, w, b, alpha=0.25, spec=SCSpec(2048), seed=3)
    pre = sc_exact_dot(x, w, SCSpec(2048), seed=3) + b
    expected = np.where(pre >= 0, pre, 0.25 * pre)
    np.testing.assert_allclose(out, expected, rtol=1e-12)
