//! The paper's case study (§IV-E, Tables III & IV): pick T = Mmax so the
//! cascade reproduces the full model's dataset accuracy exactly, and
//! report the energy savings at the paper's chosen operating points.
//!
//! Works out of the box on the synthetic fixture suite
//! (`cargo run --release --example case_study`); with `make artifacts`
//! the same driver reproduces the tables on the trained models.

use ari::runtime::{open_backend, BackendKind};

fn main() -> ari::Result<()> {
    let mut engine = open_backend(std::path::Path::new("artifacts"), BackendKind::Auto)?;
    println!("{}", ari::experiments::run_experiment(engine.as_mut(), "table3")?);
    println!("{}", ari::experiments::run_experiment(engine.as_mut(), "table4")?);
    Ok(())
}
