//! The paper's case study (§IV-E, Tables III & IV): pick T = Mmax so the
//! cascade reproduces the full model's dataset accuracy exactly, and
//! report the energy savings at the paper's chosen operating points.
//!
//! ```bash
//! make artifacts && cargo run --release --example case_study
//! ```

use ari::runtime::Engine;

fn main() -> ari::Result<()> {
    let mut engine = Engine::new(std::path::Path::new("artifacts"))?;
    println!("{}", ari::experiments::run_experiment(&mut engine, "table3")?);
    println!("{}", ari::experiments::run_experiment(&mut engine, "table4")?);
    Ok(())
}
