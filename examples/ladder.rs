//! N-level resolution ladder demo: a 3-stage FP8 → FP12 → FP16 ladder
//! end to end — per-stage calibration, whole-dataset inference with
//! per-stage escalation fractions and `E = Σ_i f_i · E_i` energy
//! accounting, then a serving session under both escalation policies.
//!
//! Works out of the box on the synthetic fixture suite:
//!
//! ```bash
//! cargo run --release --example ladder
//! ```

use ari::config::AriConfig;
use ari::coordinator::{EscalationPolicy, Ladder, LadderSpec};
use ari::runtime::{open_backend, Backend, BackendKind};
use ari::server::{run_serving_ladder, ServeOptions};

fn main() -> ari::Result<()> {
    let mut cfg = AriConfig::default();
    cfg.levels = vec![8, 12, 16]; // FP8 -> FP12 -> FP16
    cfg.reduced_level = 8;
    cfg.full_level = 16;
    cfg.requests = 1024;
    cfg.arrival_rate = 0.0; // closed loop

    let mut engine = open_backend(&cfg.artifacts, BackendKind::Auto)?;
    println!("=== ARI N-level ladder demo (backend: {}) ===\n", engine.name());
    let data = engine.eval_data(&cfg.dataset)?;

    // 1. Calibrate every non-final stage against the full model.
    let ladder = Ladder::calibrate(engine.as_mut(), LadderSpec::from_config(&cfg), &data, data.n / 2)?;
    println!("calibration ({} rows):", data.n / 2);
    print!("{}", ladder.calibration_report());

    // 2. Whole-dataset inference: where do rows stop on the ladder?
    let (out, _) = ladder.infer_dataset(engine.as_mut(), &data)?;
    let acc = out.pred.iter().zip(&data.y).filter(|(a, b)| a == b).count() as f64 / data.n as f64;
    println!("\ninfer_dataset over {} rows: accuracy {acc:.4}", data.n);
    for (i, (frac, count)) in out.stage_fractions().iter().zip(&out.stage_counts).enumerate() {
        println!("  stage {i}: executed {count} rows (f_{i} = {frac:.3})");
    }
    println!(
        "energy {:.3} µJ (= Σ f_i·E_i), savings vs always-full {:.1}%",
        out.energy_uj,
        100.0 * ladder.realised_savings(&out)
    );

    // 3. Serve the same ladder under both escalation policies.
    for (name, esc) in [("immediate", EscalationPolicy::Immediate), ("deferred", EscalationPolicy::Deferred)] {
        let report = run_serving_ladder(
            engine.as_mut(),
            &ladder,
            &cfg,
            &data,
            None,
            ServeOptions { escalation: esc },
        )?;
        println!("\n--- escalation policy: {name} ---");
        println!("{}", report.summary());
    }
    Ok(())
}
