//! Quickstart: open a backend, calibrate an ARI cascade, classify a few
//! samples, and print what the cascade decided.
//!
//! Works out of the box on the synthetic fixture suite:
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! or against real artifacts (`make artifacts`, optionally with
//! `--features pjrt` for the PJRT engine).

use ari::config::{AriConfig, Mode, ThresholdPolicy};
use ari::coordinator::{Cascade, CascadeSpec};
use ari::runtime::{open_backend, Backend, BackendKind};

fn main() -> ari::Result<()> {
    let mut cfg = AriConfig::default();
    cfg.dataset = "fashion_syn".into();
    cfg.mode = Mode::Fp;
    cfg.reduced_level = 10; // FP10: 6 mantissa bits removed from FP16
    cfg.threshold = ThresholdPolicy::MMax;
    cfg.batch_size = 32;

    let mut engine = open_backend(&cfg.artifacts, BackendKind::Auto)?;
    println!("backend: {}", engine.name());
    let data = engine.eval_data(&cfg.dataset)?;

    // Calibrate the threshold on the first half of the eval split.
    let cascade = Cascade::calibrate(engine.as_mut(), CascadeSpec::from_config(&cfg), &data, data.n / 2)?;
    println!(
        "calibrated: T = {:.4} (Mmax over {} changed elements of {})",
        cascade.threshold,
        cascade.calibration.changed_margins.len(),
        cascade.calibration.n
    );
    println!(
        "energy per inference: reduced {:.3} µJ, full {:.3} µJ",
        cascade.e_reduced, cascade.e_full
    );

    // Classify the first 32 samples with the cascade.
    let out = cascade.infer_batch(engine.as_mut(), data.rows(0, 32), 32, 0)?;
    println!("\n sample  label  pred  margin   path");
    for i in 0..32 {
        println!(
            "  {i:<6} {:<6} {:<5} {:<8.4} {}",
            data.y[i],
            out.pred[i],
            out.margin[i],
            if out.escalated[i] { "reduced -> FULL (margin below T)" } else { "reduced only" }
        );
    }
    let f = Cascade::escalation_fraction(&out);
    println!(
        "\nescalated {:.0}% of the batch; batch energy {:.2} µJ (always-full would be {:.2} µJ)",
        100.0 * f,
        out.energy_uj,
        32.0 * cascade.e_full
    );
    Ok(())
}
