//! Stochastic-computing substrate explorer: the exact bitstream simulator
//! next to the calibrated noise model, on real trained weights.
//!
//! Shows, for one eval sample and a range of sequence lengths, the
//! layer-0 MAC error of the exact LFSR/XNOR/APC simulator vs the
//! `c*sqrt(fan_in/L)` model the L1 Pallas kernel uses — the calibration
//! contract of DESIGN.md §2, on production weights rather than toy data.
//!
//! Works out of the box on the synthetic fixture suite
//! (`cargo run --release --example sc_explorer`); with `make artifacts`
//! the same driver runs on the trained weights.

use ari::mlp::{sc_exact_forward, FpEngine, ScNoiseEngine};
use ari::quant::FpFormat;
use ari::runtime::{open_backend, Backend, BackendKind};
use ari::sc::ScConfig;

fn main() -> ari::Result<()> {
    let mut engine = open_backend(std::path::Path::new("artifacts"), BackendKind::Auto)?;
    let ds = "fashion_syn";
    engine.load_dataset(ds)?;
    let data = engine.eval_data(ds)?;
    let weights = engine.weights(ds)?;

    let x = data.row(0);
    let exact_ref = FpEngine::new(weights, FpFormat::FP16).forward(x, 1);
    println!("sample 0: label={} fp16 pred={} margin={:.4}\n", data.y[0], exact_ref.pred[0], exact_ref.margin[0]);

    println!("L        exact_sim_pred  noise_model_pred  exact_time");
    for l in [256usize, 1024] {
        let cfg = ScConfig::new(l);
        let t0 = std::time::Instant::now();
        let exact = sc_exact_forward(weights, x, cfg, 7);
        let dt = t0.elapsed();
        let noise = ScNoiseEngine::new(weights, cfg).forward(x, 1, 7);
        println!("{l:<8} {:<15} {:<17} {dt:?}", exact.pred[0], noise.pred[0]);
    }

    // Layer-0 MAC error: exact simulator vs the noise model's sigma.
    println!("\nlayer-0 MAC std (first 8 neurons), exact sim vs c*sqrt(fan_in/L) model:");
    let l0 = &weights.layers[0];
    let fan_in = l0.in_dim;
    let xmax = x.iter().fold(1e-6f32, |a, &v| a.max(v.abs()));
    let wmax = l0.w.iter().fold(1e-6f32, |a, &v| a.max(v.abs()));
    let xn: Vec<f32> = x.iter().map(|&v| v / xmax).collect();
    // Keep only the first 8 output neurons (contiguous re-pack) so the
    // exact simulation stays fast.
    let n_out = 8usize;
    let mut wn = vec![0.0f32; fan_in * n_out];
    for i in 0..fan_in {
        for j in 0..n_out {
            wn[i * n_out + j] = l0.w[i * l0.out_dim + j] / wmax;
        }
    }
    // truth on normalised values
    let mut truth = vec![0.0f64; n_out];
    for i in 0..fan_in {
        for (j, t) in truth.iter_mut().enumerate() {
            *t += xn[i] as f64 * wn[i * n_out + j] as f64;
        }
    }
    for l in [512usize, 2048] {
        let cfg = ScConfig::new(l);
        let mut errs = Vec::new();
        for seed in 0..6u64 {
            let est = ari::sc::sc_dot(&xn, &wn, n_out, cfg, seed * 31 + 1);
            for j in 0..n_out {
                errs.push(est[j] - truth[j]);
            }
        }
        let emp = ari::util::Summary::of(&errs).std;
        let model = ari::mlp::SC_NOISE_C * ((fan_in as f64) / l as f64).sqrt();
        println!("  L={l:<6} empirical={emp:.3}  model={model:.3}  ratio={:.2}", emp / model);
    }
    Ok(())
}
