//! End-to-end serving driver — the repo's E2E validation (DESIGN.md §5).
//!
//! Loads the fashion_syn model (full + reduced), prints its build-time
//! training loss curve when artifacts exist, calibrates the ARI
//! threshold, serves batched requests through the stack (rust
//! coordinator -> active backend), and reports latency/throughput,
//! escalation fraction, accuracy parity with the always-full baseline,
//! and modelled energy savings.  The run is recorded in EXPERIMENTS.md
//! §E2E.
//!
//! Works out of the box on the synthetic fixture suite
//! (`cargo run --release --example ari_serving`); with `make artifacts`
//! and `--features pjrt` the same driver exercises the full three-layer
//! PJRT stack.

use ari::config::{AriConfig, Mode, ThresholdPolicy};
use ari::coordinator::{Cascade, CascadeSpec, EscalationPolicy};
use ari::runtime::{open_backend, Backend, BackendKind};
use ari::server::{run_serving, ServeOptions};

fn main() -> ari::Result<()> {
    let mut cfg = AriConfig::default();
    cfg.dataset = "fashion_syn".into();
    cfg.mode = Mode::Fp;
    cfg.reduced_level = 10;
    cfg.full_level = 16;
    cfg.threshold = ThresholdPolicy::MMax;
    cfg.batch_size = 32;
    cfg.batch_timeout_us = 2000;
    cfg.requests = 2048;
    cfg.arrival_rate = 0.0; // closed loop: measure peak throughput

    println!("=== ARI end-to-end serving driver ===\n");

    // 1. The build-time training loss curve (L2, recorded by make artifacts).
    let log_path = cfg.artifacts.join(&cfg.dataset).join("train_log.txt");
    if let Ok(log) = std::fs::read_to_string(&log_path) {
        println!("build-time training curve ({}):", cfg.dataset);
        for line in log.lines() {
            println!("  {line}");
        }
        println!();
    }

    // 2. Load + calibrate.
    let mut engine = open_backend(&cfg.artifacts, BackendKind::Auto)?;
    println!("backend: {}\n", engine.name());
    let data = engine.eval_data(&cfg.dataset)?;
    let t0 = std::time::Instant::now();
    let cascade = Cascade::calibrate(engine.as_mut(), CascadeSpec::from_config(&cfg), &data, data.n / 2)?;
    println!(
        "calibration: {:?} over {} rows -> T = {:.4} ({} changed elements)",
        t0.elapsed(),
        data.n / 2,
        cascade.threshold,
        cascade.calibration.changed_margins.len()
    );

    // 3. Baseline: always-full predictions (for parity + energy compare).
    let full_v = engine
        .manifest()
        .variant(&cfg.dataset, cfg.mode.kind(), cfg.full_level, cfg.batch_size)?
        .clone();
    let full_out = engine.run_dataset(&full_v, &data, cfg.seed as u32)?;
    println!("always-full baseline accuracy: {:.4}\n", full_out.accuracy(&data.y));

    // 4. Serve, both escalation policies.
    for (name, esc) in [("immediate", EscalationPolicy::Immediate), ("deferred", EscalationPolicy::Deferred)] {
        let report = run_serving(
            engine.as_mut(),
            &cascade,
            &cfg,
            &data,
            Some(&full_out.pred),
            ServeOptions { escalation: esc },
        )?;
        println!("--- escalation policy: {name} ---");
        println!("{}\n", report.summary());
    }

    // 5. Runtime statistics.
    let stats = engine.stats();
    println!(
        "engine: {} compiles ({} ms), {} executes, mean {:.0} µs/batch, {:.1} MiB host->device",
        stats.compiles,
        stats.compile_ms,
        stats.executes,
        engine.mean_execute_us(),
        stats.h2d_bytes as f64 / (1024.0 * 1024.0)
    );
    Ok(())
}
